"""AST determinism linter for the compiler source tree.

The repo's core contract is bit-reproducibility: the same specs +
config must compile to byte-identical artifacts on every backend, in
every process of the farm, forever.  Four classes of source construct
quietly break that contract; this pass flags them:

  - ``unseeded-rng``   — module-level ``np.random.*`` / ``random.*``
    calls and ``default_rng()`` with no seed: results change run to
    run.
  - ``wall-clock``     — ``time.time`` / ``perf_counter`` /
    ``monotonic`` / ``datetime.now`` reads: fine for *reporting*
    (benchmark walls), poison when they feed anything content-addressed
    or compared across processes.
  - ``set-iteration``  — a ``for`` loop / list- or generator-
    comprehension over a set expression: iteration order is
    hash-seed-dependent, so any *ordered* output it feeds (a list, a
    schedule, a cache key) becomes nondeterministic.  Iterating into
    an unordered sink (set/dict comprehension) is fine; so is
    ``sorted(set(...))``.
  - ``float-accum``    — ``sum()`` over a set expression: float
    addition does not commute, so an unordered iterable makes the
    total hash-seed-dependent.

Intentional uses carry an inline ``# pfdnn: allow(<rule>)`` suppression
on the flagged line (self-documenting at the use site), or an entry in
a committed baseline file (``--write-baseline``) keyed by
``(relative path, rule, stripped source line)`` so line-number churn
does not invalidate it.
"""

from __future__ import annotations

import ast
import dataclasses
import json
import pathlib
import re

RULES = ("unseeded-rng", "wall-clock", "set-iteration", "float-accum")

#: (path substring, rule) pairs exempt by design: the calibration
#: harness's measure loop and the launch wrappers report wall time by
#: construction (their walls never feed content-addressed state)
DEFAULT_ALLOWLIST: tuple[tuple[str, str], ...] = ()

_ALLOW_RE = re.compile(
    r"#\s*pfdnn:\s*allow\(\s*([a-z0-9_,\s-]+?)\s*\)")

#: wall-clock reads (resolved through import aliases)
_WALL_CLOCK_FNS = frozenset({
    "time.time", "time.time_ns", "time.perf_counter",
    "time.perf_counter_ns", "time.monotonic", "time.monotonic_ns",
    "time.process_time", "datetime.datetime.now",
    "datetime.datetime.utcnow", "datetime.date.today",
})

#: numpy.random constructors that are deterministic once seeded
_SEEDED_RNG_CTORS = frozenset({
    "numpy.random.default_rng", "numpy.random.Generator",
    "numpy.random.SeedSequence", "numpy.random.PCG64",
    "numpy.random.Philox", "numpy.random.RandomState",
})


@dataclasses.dataclass(frozen=True)
class Finding:
    path: str
    line: int
    col: int
    rule: str
    message: str
    text: str           # the stripped source line (baseline key part)

    def fingerprint(self) -> tuple[str, str, str]:
        return (self.path, self.rule, self.text)

    def __str__(self) -> str:
        return (f"{self.path}:{self.line}:{self.col}: [{self.rule}] "
                f"{self.message}")


class _Visitor(ast.NodeVisitor):
    def __init__(self, path: str, lines: list[str]) -> None:
        self.path = path
        self.lines = lines
        self.aliases: dict[str, str] = {}   # local name -> dotted module
        self.findings: list[Finding] = []

    # ---- import alias tracking
    def visit_Import(self, node: ast.Import) -> None:
        for alias in node.names:
            self.aliases[alias.asname or alias.name.split(".")[0]] = \
                alias.name if alias.asname else alias.name.split(".")[0]
        self.generic_visit(node)

    def visit_ImportFrom(self, node: ast.ImportFrom) -> None:
        if node.module and node.level == 0:
            for alias in node.names:
                self.aliases[alias.asname or alias.name] = \
                    f"{node.module}.{alias.name}"
        self.generic_visit(node)

    # ---- helpers
    def _dotted(self, node: ast.expr) -> str | None:
        """Resolve ``np.random.rand`` → ``numpy.random.rand`` using the
        recorded import aliases; None for non-name expressions."""
        parts: list[str] = []
        while isinstance(node, ast.Attribute):
            parts.append(node.attr)
            node = node.value
        if not isinstance(node, ast.Name):
            return None
        root = self.aliases.get(node.id, node.id)
        if node.id == "np":
            root = "numpy"
        return ".".join([root] + list(reversed(parts)))

    def _emit(self, node: ast.AST, rule: str, message: str) -> None:
        line = node.lineno
        text = self.lines[line - 1].strip() if line <= len(self.lines) \
            else ""
        self.findings.append(Finding(
            path=self.path, line=line, col=node.col_offset,
            rule=rule, message=message, text=text))

    @staticmethod
    def _is_set_expr(node: ast.expr) -> bool:
        if isinstance(node, (ast.Set, ast.SetComp)):
            return True
        if isinstance(node, ast.Call) and isinstance(node.func, ast.Name) \
                and node.func.id in ("set", "frozenset"):
            return True
        if isinstance(node, ast.BinOp) and isinstance(
                node.op, (ast.BitOr, ast.BitAnd, ast.Sub, ast.BitXor)):
            # set algebra: flag only when a side is itself set-ish
            return (_Visitor._is_set_expr(node.left)
                    or _Visitor._is_set_expr(node.right))
        return False

    # ---- rules
    def visit_Call(self, node: ast.Call) -> None:
        dotted = self._dotted(node.func)
        if dotted:
            if dotted.startswith("numpy.random."):
                if dotted in _SEEDED_RNG_CTORS:
                    if not node.args and not node.keywords:
                        self._emit(node, "unseeded-rng",
                                   f"{dotted}() without a seed")
                else:
                    self._emit(node, "unseeded-rng",
                               f"module-level RNG call {dotted}()")
            elif dotted.startswith("random."):
                if dotted in ("random.Random", "random.SystemRandom"):
                    if not node.args and not node.keywords:
                        self._emit(node, "unseeded-rng",
                                   f"{dotted}() without a seed")
                else:
                    self._emit(node, "unseeded-rng",
                               f"stdlib RNG call {dotted}()")
            elif dotted in _WALL_CLOCK_FNS:
                self._emit(node, "wall-clock",
                           f"wall-clock read {dotted}()")
        if isinstance(node.func, ast.Name) and node.func.id == "sum" \
                and node.args and self._is_set_expr(node.args[0]):
            self._emit(node, "float-accum",
                       "sum() over an unordered set — float addition "
                       "does not commute")
        self.generic_visit(node)

    def visit_For(self, node: ast.For) -> None:
        if self._is_set_expr(node.iter):
            self._emit(node, "set-iteration",
                       "for-loop over a set expression — iteration "
                       "order is hash-seed-dependent")
        self.generic_visit(node)

    def _check_comp(self, node, kind: str) -> None:
        for gen in node.generators:
            if self._is_set_expr(gen.iter):
                self._emit(node, "set-iteration",
                           f"{kind} over a set expression feeds an "
                           "ordered output")
        self.generic_visit(node)

    def visit_ListComp(self, node: ast.ListComp) -> None:
        self._check_comp(node, "list comprehension")

    def visit_GeneratorExp(self, node: ast.GeneratorExp) -> None:
        self._check_comp(node, "generator expression")


def _allowed_rules_on_line(line: str) -> set[str]:
    m = _ALLOW_RE.search(line)
    if not m:
        return set()
    return {r.strip() for r in m.group(1).split(",") if r.strip()}


def lint_source(source: str, path: str) -> list[Finding]:
    """Lint one module's source.  Findings suppressed by an inline
    ``# pfdnn: allow(<rule>)`` on their line are dropped here."""
    lines = source.splitlines()
    tree = ast.parse(source, filename=path)
    visitor = _Visitor(path, lines)
    visitor.visit(tree)
    out = []
    for f in visitor.findings:
        raw = lines[f.line - 1] if f.line <= len(lines) else ""
        if f.rule in _allowed_rules_on_line(raw):
            continue
        out.append(f)
    return out


def lint_tree(root, *, allowlist=DEFAULT_ALLOWLIST) -> list[Finding]:
    """Lint every ``*.py`` under ``root`` (paths reported relative to
    it).  ``allowlist`` drops (path substring, rule) matches."""
    rootp = pathlib.Path(root)
    findings: list[Finding] = []
    for path in sorted(rootp.rglob("*.py")):
        rel = path.relative_to(rootp).as_posix()
        for f in lint_source(path.read_text(), rel):
            if any(sub in rel and rule == f.rule
                   for sub, rule in allowlist):
                continue
            findings.append(f)
    return findings


# ------------------------------------------------------------- baseline

def load_baseline(path) -> set[tuple[str, str, str]]:
    p = pathlib.Path(path)
    if not p.exists():
        return set()
    entries = json.loads(p.read_text())
    return {(e["path"], e["rule"], e["text"]) for e in entries}


def save_baseline(path, findings: list[Finding]) -> None:
    entries = [{"path": f.path, "rule": f.rule, "text": f.text}
               for f in findings]
    entries.sort(key=lambda e: (e["path"], e["rule"], e["text"]))
    pathlib.Path(path).write_text(json.dumps(entries, indent=2) + "\n")


def apply_baseline(findings: list[Finding], baseline) \
        -> tuple[list[Finding], list[Finding]]:
    """Split ``findings`` into (new, baseline-suppressed)."""
    new, suppressed = [], []
    for f in findings:
        (suppressed if f.fingerprint() in baseline else new).append(f)
    return new, suppressed
