"""Lock-order analysis for the concurrent compile machinery.

The farm/service stack holds several locks (artifact store, disk tier,
stack caches, master tables, async dispatch) whose discipline — never
acquire them in inconsistent orders, never hold one across the
``compile_many`` stacked-sweep barrier — is enforced only by
convention.  This module makes the convention checkable:

**Runtime instrumentation** (opt-in, ``PFDNN_LOCKCHECK=1``): every
lock in the service/core paths is constructed through
:func:`make_lock`.  Normally that returns a plain
``threading.Lock``/``RLock`` with zero overhead; under instrumentation
it returns a named wrapper that records, per thread, the stack of held
locks and adds an edge ``held → acquired`` to a process-global
acquisition graph on every nested acquire.  A cycle in that graph is a
lock-order inversion (two call paths that could deadlock under the
right interleaving); :func:`barrier` marks dispatch points that must
never be reached with a lock held (the ``compile_many`` stacked-sweep
round loop blocks on worker completion — holding a store lock there
starves every other compilation).

Worker *processes* (the compile farm) each build their own graph; when
``PFDNN_LOCKCHECK_DUMP=<path>`` is set, every process appends its graph
as one JSON line at exit, and ``python -m repro.analysis lockcheck
--dump <path>`` merges and checks the union.

**Static companion**: :func:`static_lock_nesting` AST-scans the
service/core modules for textually nested ``with <lock>`` blocks and
cross-checks them against the recorded runtime graph — a static
nesting that never showed up at runtime means the test suite did not
exercise that path (reported as uncovered, not an error).

Stdlib-only on purpose: ``repro.core`` and ``repro.service`` import
this module at module scope, so it must never import them back.
"""

from __future__ import annotations

import ast
import dataclasses
import json
import os
import pathlib
import threading

__all__ = [
    "LockOrderError",
    "make_lock",
    "barrier",
    "enabled",
    "enable",
    "disable",
    "reset",
    "graph",
    "find_cycles",
    "check",
    "assert_clean",
    "dump",
    "merge_dumps",
    "static_lock_nesting",
    "cross_check",
]


class LockOrderError(AssertionError):
    """A lock-order inversion (cycle) or barrier hazard was recorded."""


# ------------------------------------------------------ recorder state

class _Recorder:
    """Process-global acquisition recorder (one per enable())."""

    def __init__(self) -> None:
        self.mu = threading.Lock()
        # (held_name, acquired_name) -> acquisition count
        self.edges: dict[tuple[str, str], int] = {}
        # barrier tag -> sorted tuples of lock names held when crossed
        self.hazards: dict[str, list[list[str]]] = {}
        self.locks_seen: set[str] = set()
        self.local = threading.local()

    def held_stack(self) -> list[str]:
        st = getattr(self.local, "stack", None)
        if st is None:
            st = self.local.stack = []
        return st

    def on_acquire(self, name: str) -> None:
        held = self.held_stack()
        with self.mu:
            self.locks_seen.add(name)
            for h in held:
                if h != name:          # re-entrant self-acquire is fine
                    key = (h, name)
                    self.edges[key] = self.edges.get(key, 0) + 1
        held.append(name)

    def on_release(self, name: str) -> None:
        held = self.held_stack()
        # release the innermost matching hold (RLock release order)
        for i in range(len(held) - 1, -1, -1):
            if held[i] == name:
                del held[i]
                return

    def on_barrier(self, tag: str) -> None:
        held = self.held_stack()
        if held:
            with self.mu:
                self.hazards.setdefault(tag, []).append(list(held))


_RECORDER: _Recorder | None = None
_ENV_FLAG = "PFDNN_LOCKCHECK"
_ENV_DUMP = "PFDNN_LOCKCHECK_DUMP"


def enabled() -> bool:
    """True when acquisitions are being recorded in this process."""
    return _RECORDER is not None


def enable() -> None:
    """Start recording (idempotent).  Locks constructed *after* this
    call are instrumented; existing plain locks stay plain."""
    global _RECORDER
    if _RECORDER is None:
        _RECORDER = _Recorder()


def disable() -> None:
    global _RECORDER
    _RECORDER = None


def reset() -> None:
    """Drop every recorded edge/hazard (keeps recording enabled)."""
    global _RECORDER
    if _RECORDER is not None:
        _RECORDER = _Recorder()


if os.environ.get(_ENV_FLAG) == "1":
    enable()
    if os.environ.get(_ENV_DUMP):
        import atexit

        atexit.register(lambda: dump(os.environ[_ENV_DUMP]))


# ------------------------------------------------------ the lock wrapper

class _InstrumentedLock:
    """Named lock recording nested acquisitions.  Wraps a real
    ``Lock``/``RLock`` — blocking semantics are unchanged; only
    successful acquires/releases touch the (thread-local) held stack."""

    __slots__ = ("name", "_lock")

    def __init__(self, name: str, reentrant: bool) -> None:
        self.name = name
        self._lock = threading.RLock() if reentrant else threading.Lock()

    def acquire(self, blocking: bool = True, timeout: float = -1) -> bool:
        ok = self._lock.acquire(blocking, timeout)
        if ok and _RECORDER is not None:
            _RECORDER.on_acquire(self.name)
        return ok

    def release(self) -> None:
        if _RECORDER is not None:
            _RECORDER.on_release(self.name)
        self._lock.release()

    def locked(self) -> bool:
        return self._lock.locked()

    def __enter__(self) -> bool:
        return self.acquire()

    def __exit__(self, *exc) -> None:
        self.release()

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"<InstrumentedLock {self.name!r}>"


def make_lock(name: str, *, reentrant: bool = False):
    """Construct a lock for the service/core machinery.

    Returns a plain ``threading.Lock``/``RLock`` (zero overhead) unless
    lock-order recording is enabled in this process, in which case the
    lock is a named :class:`_InstrumentedLock`.  ``name`` should be the
    ``<module>.<attr>`` the static companion will see (e.g.
    ``"store._lock"``).
    """
    if _RECORDER is None:
        return threading.RLock() if reentrant else threading.Lock()
    return _InstrumentedLock(name, reentrant)


def barrier(tag: str) -> None:
    """Mark a dispatch point that must never be crossed holding an
    instrumented lock (e.g. the ``compile_many`` stacked-sweep round
    loop).  No-op unless recording is enabled."""
    if _RECORDER is not None:
        _RECORDER.on_barrier(tag)


# ------------------------------------------------------ graph queries

def graph() -> dict:
    """Snapshot of the recorded acquisition graph."""
    if _RECORDER is None:
        return {"edges": {}, "hazards": {}, "locks": []}
    with _RECORDER.mu:
        return {
            "edges": {f"{a} -> {b}": n
                      for (a, b), n in sorted(_RECORDER.edges.items())},
            "hazards": {t: [list(h) for h in hs]
                        for t, hs in sorted(_RECORDER.hazards.items())},
            "locks": sorted(_RECORDER.locks_seen),
        }


def find_cycles(edges) -> list[list[str]]:
    """Elementary cycles (as node lists) in an ``{(a, b): n}`` or
    ``[(a, b), ...]`` edge collection, via iterative DFS."""
    adj: dict[str, list[str]] = {}
    for a, b in edges:
        adj.setdefault(a, []).append(b)
        adj.setdefault(b, [])
    cycles: list[list[str]] = []
    seen_cycles: set[tuple[str, ...]] = set()
    color: dict[str, int] = {}          # 0 unvisited, 1 on stack, 2 done

    def dfs(start: str) -> None:
        stack: list[tuple[str, iter]] = [(start, iter(adj[start]))]
        path = [start]
        color[start] = 1
        while stack:
            node, it = stack[-1]
            advanced = False
            for nxt in it:
                if color.get(nxt, 0) == 1:
                    i = path.index(nxt)
                    cyc = path[i:]
                    # canonicalize rotation so each cycle reports once
                    j = cyc.index(min(cyc))
                    canon = tuple(cyc[j:] + cyc[:j])
                    if canon not in seen_cycles:
                        seen_cycles.add(canon)
                        cycles.append(list(canon))
                elif color.get(nxt, 0) == 0:
                    color[nxt] = 1
                    path.append(nxt)
                    stack.append((nxt, iter(adj[nxt])))
                    advanced = True
                    break
            if not advanced:
                color[node] = 2
                path.pop()
                stack.pop()

    for node in sorted(adj):
        if color.get(node, 0) == 0:
            dfs(node)
    return cycles


def check(extra_edges=()) -> dict:
    """Cycle/hazard report over the recorded graph (plus optional
    merged edges from other processes)."""
    g = graph()
    edges = [tuple(k.split(" -> ")) for k in g["edges"]]
    edges += [tuple(e) for e in extra_edges]
    cycles = find_cycles(edges)
    hazards = [
        {"barrier": tag, "held": held}
        for tag, holds in g["hazards"].items() for held in holds
    ]
    return {"edges": sorted(set(edges)), "cycles": cycles,
            "hazards": hazards,
            "ok": not cycles and not hazards}


def assert_clean(extra_edges=()) -> dict:
    """Raise :class:`LockOrderError` on any cycle or barrier hazard."""
    report = check(extra_edges)
    if not report["ok"]:
        raise LockOrderError(
            "lock discipline violated: "
            f"cycles={report['cycles']} hazards={report['hazards']}")
    return report


# ------------------------------------------------------ dump / merge

def dump(path) -> None:
    """Append this process's graph as one JSON line (atomic enough:
    a single ``write`` of one line in append mode)."""
    if _RECORDER is None:
        return
    line = json.dumps({"pid": os.getpid(), **graph()}) + "\n"
    with open(path, "a") as fh:
        fh.write(line)


def merge_dumps(path) -> dict:
    """Union of every dumped per-process graph at ``path``."""
    edges: dict[tuple[str, str], int] = {}
    hazards: list[dict] = []
    locks: set[str] = set()
    text = pathlib.Path(path).read_text()
    for line in text.splitlines():
        if not line.strip():
            continue
        rec = json.loads(line)
        for key, n in rec.get("edges", {}).items():
            a, b = key.split(" -> ")
            edges[(a, b)] = edges.get((a, b), 0) + n
        for tag, holds in rec.get("hazards", {}).items():
            for held in holds:
                hazards.append({"barrier": tag, "held": held,
                                "pid": rec.get("pid")})
        locks.update(rec.get("locks", []))
    return {"edges": edges, "hazards": hazards, "locks": sorted(locks)}


# ------------------------------------------------------ static companion

#: attribute names that hold locks in the scanned modules
_LOCK_ATTRS = frozenset({"_lock", "_async_lock", "_master_lock",
                         "agg_lock", "lock"})

#: a static ``<module>.<attr>`` name may correspond to several runtime
#: lock names (one module can define locks on several classes)
STATIC_ALIASES: dict[str, tuple[str, ...]] = {
    "backend._lock": ("backend.bucket._lock", "backend.stacks._lock"),
    "rails.lock": ("rails._sweep_lock",),
    "policies.agg_lock": ("policies._agg_lock",),
}


@dataclasses.dataclass(frozen=True)
class StaticNesting:
    """One textually nested ``with <lock>`` pair."""

    outer: str
    inner: str
    path: str
    line: int


def _lock_name(node: ast.expr, module: str) -> str | None:
    """``self._lock`` / ``lock`` / ``obj.agg_lock`` → ``module.attr``."""
    if isinstance(node, ast.Attribute) and node.attr in _LOCK_ATTRS:
        return f"{module}.{node.attr}"
    if isinstance(node, ast.Name) and node.id in _LOCK_ATTRS:
        return f"{module}.{node.id}"
    return None


class _WithNestingVisitor(ast.NodeVisitor):
    def __init__(self, module: str, path: str) -> None:
        self.module = module
        self.path = path
        self.stack: list[str] = []
        self.found: list[StaticNesting] = []

    def visit_With(self, node: ast.With) -> None:
        names = [n for item in node.items
                 if (n := _lock_name(item.context_expr,
                                     self.module)) is not None]
        for name in names:
            for outer in self.stack:
                if outer != name:
                    self.found.append(StaticNesting(
                        outer, name, self.path, node.lineno))
        self.stack.extend(names)
        self.generic_visit(node)
        for _ in names:
            self.stack.pop()

    # a nested function/lambda body does not run under the enclosing
    # ``with`` at definition time — reset the stack across boundaries
    def visit_FunctionDef(self, node):  # noqa: N802
        saved, self.stack = self.stack, []
        self.generic_visit(node)
        self.stack = saved

    visit_AsyncFunctionDef = visit_FunctionDef

    def visit_Lambda(self, node):  # noqa: N802
        saved, self.stack = self.stack, []
        self.generic_visit(node)
        self.stack = saved


def static_lock_nesting(root) -> list[StaticNesting]:
    """Scan ``root`` (a directory of Python modules, typically
    ``src/repro``) for textually nested ``with <lock>`` acquisitions."""
    rootp = pathlib.Path(root)
    out: list[StaticNesting] = []
    for path in sorted(rootp.rglob("*.py")):
        tree = ast.parse(path.read_text(), filename=str(path))
        visitor = _WithNestingVisitor(path.stem, str(path))
        visitor.visit(tree)
        out.extend(visitor.found)
    return out


def cross_check(static: list[StaticNesting],
                runtime_edges) -> dict:
    """Compare static ``with``-nesting pairs against the recorded
    runtime acquisition graph.

    Every static pair should be *covered* by a runtime edge (under
    :data:`STATIC_ALIASES` name expansion) — an uncovered pair means
    the instrumented run never exercised that nesting.  Coverage gaps
    are reported, not fatal; cycles among the static pairs themselves
    are fatal (they are order inversions visible in the source).
    """
    runtime = {tuple(e) for e in runtime_edges}

    def aliases(name: str) -> tuple[str, ...]:
        return STATIC_ALIASES.get(name, (name,))

    uncovered = []
    static_edges = set()
    for nest in static:
        static_edges.add((nest.outer, nest.inner))
        covered = any((a, b) in runtime
                      for a in aliases(nest.outer)
                      for b in aliases(nest.inner))
        if not covered:
            uncovered.append(dataclasses.asdict(nest))
    cycles = find_cycles(sorted(static_edges))
    return {"static_pairs": sorted(static_edges),
            "uncovered": uncovered,
            "static_cycles": cycles,
            "ok": not cycles}
