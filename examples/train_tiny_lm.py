"""Train a reduced LM for a few hundred steps with checkpointing and a
mid-run restart — the training-substrate example.

    PYTHONPATH=src python examples/train_tiny_lm.py
"""

import pathlib
import tempfile

from repro.configs import get_config
from repro.data.pipeline import DataConfig
from repro.train.elastic import ElasticRun, run_elastic
from repro.train.optimizer import AdamWConfig
from repro.train.trainer import TrainConfig

cfg = get_config("tinyllama-1.1b").reduced(
    n_layers=2, d_model=128, d_ff=256, vocab_size=512)
steps = 200

with tempfile.TemporaryDirectory() as tmp:
    run = ElasticRun(
        cfg=cfg,
        tcfg=TrainConfig(optimizer=AdamWConfig(
            lr=3e-3, warmup_steps=10, total_steps=steps)),
        data=DataConfig(vocab_size=cfg.vocab_size, seq_len=64,
                        global_batch=8),
        ckpt_dir=pathlib.Path(tmp) / "ckpt",
        ckpt_every=50,
    )
    # phase 1: train half way
    out = run_elastic(run, total_steps=steps // 2)
    print(f"phase 1: steps 0..{steps//2 - 1}, "
          f"loss {out['history'][0]['loss']:.3f} → "
          f"{out['history'][-1]['loss']:.3f}")
    # phase 2: fresh process semantics — restore and continue
    out = run_elastic(run, total_steps=steps)
    print(f"phase 2: resumed from step {out['resumed_from']}, "
          f"final loss {out['history'][-1]['loss']:.3f}")
    first = out["history"][0]
    last = out["history"][-1]
    assert last["loss"] < 4.0, "training did not converge"
    print("done — loss decreased across restart without a hiccup.")
