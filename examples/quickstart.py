"""Quickstart: compile a PF-DNN power schedule for SqueezeNet at 40 fps
and inspect it.

Compilation is goal-driven: the objective is a first-class value
(``MinEnergy`` here — the paper's min-energy-under-deadline scenario;
see examples/energy_budget.py for the dual and the Pareto frontier).
An impossible goal comes back as a structured ``InfeasibleGoal``
instead of ``None``.

    PYTHONPATH=src python examples/quickstart.py
"""

from repro.core import (
    InfeasibleGoal,
    MinEnergy,
    OrchestratorConfig,
    compile,
)
from repro.hw.edge40nm import EDGE40NM_DEFAULT
from repro.models.edge_cnn import edge_network
from repro.perfmodel import characterize_network, plan_banks
from repro.serve import PowerRuntime

# 1. the workload: SqueezeNet1.1 as a sequence of scheduled operations
specs = edge_network("squeezenet1.1")
print(f"workload: {len(specs)} layers, "
      f"{sum(s.macs for s in specs)/1e6:.0f} MMACs, "
      f"{sum(s.weight_bytes for s in specs)/1e6:.2f} MB weights")

# 2. compile: unified DVFS + power-gating schedule under a 25 ms deadline
goal = MinEnergy(rate_hz=40.0)
for policy in ("baseline", "greedy_gating", "pfdnn"):
    sched = compile(specs, goal,
                    cfg=OrchestratorConfig(policy=policy),
                    network="squeezenet1.1")
    print(sched.summary())

# 3. the compiled artifact: per-anchor register writes for the pg_manager
sched = compile(specs, goal, cfg=OrchestratorConfig(policy="pfdnn"),
                network="squeezenet1.1")
assert not isinstance(sched, InfeasibleGoal)   # 40 fps is attainable
prog = sched.program()
print(f"\ncompiled program: {len(prog)} register writes; first 6:")
for op in prog[:6]:
    print("  ", op)

# an impossible deadline is a structured result, not a bare None
impossible = compile(specs, MinEnergy(rate_hz=1e6),
                     cfg=OrchestratorConfig(policy="pfdnn"),
                     network="squeezenet1.1")
print(f"\n{impossible.summary()}")

# 4. execute one interval on the power runtime and verify the ledger
costs = characterize_network(specs, EDGE40NM_DEFAULT)
plan = plan_banks(costs, EDGE40NM_DEFAULT)
ledger = PowerRuntime(sched, costs, plan,
                      EDGE40NM_DEFAULT).execute_interval()
print(f"\nexecuted interval: {ledger.e_total*1e6:.2f} uJ "
      f"(compiler predicted {sched.e_total*1e6:.2f} uJ), "
      f"deadline {'met' if ledger.met_deadline else 'MISSED'}")
