"""Multi-pod dry-run, scripted: lower + compile one cell on the 512-chip
mesh and print its roofline terms.

    PYTHONPATH=src python examples/multipod_dryrun_demo.py
"""

# NOTE: repro.launch.dryrun sets
#   XLA_FLAGS=--xla_force_host_platform_device_count=512
# as its first import action, so importing it FIRST is required.
from repro.launch.dryrun import run_cell  # noqa: E402  (sets XLA_FLAGS)

rec = run_cell("tinyllama-1.1b", "train_4k", multi_pod=True)
print(f"status:   {rec['status']}  mesh={rec['mesh']} "
      f"devices={rec.get('devices')}")
if rec["status"] == "OK":
    m = rec["memory"]
    c = rec["cost"]
    print(f"memory:   peak {m['peak_bytes']/2**30:.2f} GiB/device "
          f"(args {m['argument_bytes']/2**30:.2f}, "
          f"temps {m['temp_bytes']/2**30:.2f})")
    print(f"compute:  {c['flops_per_device']/1e12:.2f} TFLOP/device "
          f"→ {c['flops_per_device']/197e12:.4f} s at 197 TF/s")
    print(f"memory:   {c['bytes_per_device']/1e9:.1f} GB/device "
          f"→ {c['bytes_per_device']/819e9:.4f} s at 819 GB/s")
    print(f"network:  {c['collective_bytes_per_device']/1e9:.2f} "
          f"GB/device → "
          f"{c['collective_bytes_per_device']/50e9:.4f} s at 50 GB/s")
    by_op = c["collective_by_op_per_device"]
    print("collectives by op:",
          {k: f"{v/1e9:.2f}GB" for k, v in by_op.items()})
