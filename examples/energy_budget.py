"""Goal-driven compilation beyond the paper's scenario: the dual
(fastest inference under an energy budget) and the whole energy–latency
Pareto frontier.

A battery-powered deployment often asks the dual question — "this
inference may spend 250 µJ; how fast can it run?" — and a design-space
exploration wants the whole tradeoff curve.  Both reuse the compiler's
λ-parameterized DP: the dual bisects the energy axis of the λ envelope,
the frontier co-schedules one MinEnergy sweep per deadline through the
stacked round scheduler so the curve costs little more than one
compile.

    PYTHONPATH=src python examples/energy_budget.py
"""

from repro.core import (
    MinEnergy,
    MinLatency,
    OrchestratorConfig,
    ParetoFront,
    compile,
)
from repro.models.edge_cnn import edge_network

specs = edge_network("squeezenet1.1")
cfg = OrchestratorConfig(policy="pfdnn", n_max_rails=2)

# 1. anchor: the paper's min-energy compile at 40 fps
ref = compile(specs, MinEnergy(rate_hz=40.0), cfg=cfg,
              network="squeezenet1.1")
print("MinEnergy @40fps:", ref.summary())

# 2. the dual: fastest schedule within an energy budget.  The artifact
# has zero slack (t_max == t_infer) and the budget is binding.
for headroom in (1.05, 1.5, 3.0):
    budget = (ref.e_op + ref.e_trans) * headroom
    fast = compile(specs, MinLatency(energy_budget_j=budget), cfg=cfg,
                   network="squeezenet1.1")
    print(f"MinLatency @{budget*1e6:7.2f}uJ: T={fast.t_infer*1e3:7.3f}ms"
          f"  E={(fast.e_op + fast.e_trans)*1e6:7.2f}uJ"
          f"  rails={fast.rails}")

# an unpayable budget is a structured diagnosis with the bound needed
# to renegotiate
broke = compile(specs, MinLatency(energy_budget_j=1e-9), cfg=cfg,
                network="squeezenet1.1")
print(broke.summary())

# 3. the frontier: 6 co-scheduled MinEnergy points spanning the
# operating band — identical to 6 independent compiles, for little
# more than the cost of one
frontier = compile(specs, ParetoFront(n_points=6), cfg=cfg,
                   network="squeezenet1.1")
print("\n" + frontier.summary())
