"""End-to-end driver: serve a small LM with batched requests while
PF-DNN-compiled power schedules govern the co-hosted periodic edge
workload — the paper's deployment story, end to end.

All deployment points compile through the fleet `CompileService`: one
`compile_many` call co-schedules every rail sweep in one round
scheduler (cross-network bucket stacking), the process-wide artifact
store amortizes characterization / master tables / transitions across
the rates, and repeat requests answer from the schedule cache.

    PYTHONPATH=src python examples/power_orchestrated_serving.py
"""

import jax
import numpy as np

from repro.configs import get_config
from repro.core import OrchestratorConfig
from repro.hw.edge40nm import EDGE40NM_DEFAULT
from repro.models.edge_cnn import edge_network
from repro.models.transformer import init_params
from repro.perfmodel import characterize_network, plan_banks
from repro.serve import (
    AdaptiveScheduler,
    CompileRequest,
    CompileService,
    EngineConfig,
    FaultConfig,
    FaultInjector,
    PeriodicScheduler,
    PowerRuntime,
    ServingEngine,
    StaticSchedulePolicy,
    TrafficConfig,
    TrafficSimulator,
    serve_trace,
)

# ---- LM serving side: continuous batching over a reduced qwen2 ----
cfg = get_config("qwen2-7b").reduced()
params, _ = init_params(cfg, jax.random.PRNGKey(0))
engine = ServingEngine(cfg, params, EngineConfig(
    max_batch=4, cache_len=96, max_new_tokens=12, eos_token=-1))

rng = np.random.default_rng(0)
for i in range(10):
    engine.submit(list(rng.integers(1, cfg.vocab_size,
                                    int(rng.integers(4, 20)))))
done = engine.run_to_completion()
print(f"[serving] {len(done)} requests completed, "
      f"{sum(len(r.generated) for r in done)} tokens")

# ---- power-orchestrated periodic inference at 3 frame rates ----
specs = edge_network("mobilenetv3-small")
costs = characterize_network(specs, EDGE40NM_DEFAULT)
plan = plan_banks(costs, EDGE40NM_DEFAULT)
service = CompileService(EDGE40NM_DEFAULT)    # one per accelerator
points = [(rate, policy)
          for rate in (30.0, 90.0, 180.0)
          for policy in ("greedy_gating", "pfdnn")]
schedules = service.compile_many([
    CompileRequest(specs, rate, OrchestratorConfig(policy=policy),
                   network="mnv3-small")
    for rate, policy in points])
print("\n[power] rate (Hz) | policy        | uJ/interval | avg mW")
for (rate, policy), sched in zip(points, schedules):
    if sched is None:
        print(f"   {rate:7.0f} | {policy:13s} | infeasible")
        continue
    stats = PeriodicScheduler(
        PowerRuntime(sched, costs, plan, EDGE40NM_DEFAULT),
        rate).run(n_intervals=20)
    print(f"   {rate:7.0f} | {policy:13s} | "
          f"{stats['avg_interval_energy_uj']:11.2f} | "
          f"{stats['avg_power_mw']:6.3f}")
print(f"[power] store after the fleet compile: "
      f"{service.store.stats()['schedules']} cached schedules, "
      f"{service.store.stats()['resident_lanes']} resident lanes")
print("\nPF-DNN matches greedy+gating at low rates (abundant slack) and "
      "wins at high rates — paper §6.1.")

# ---- online serving under bursty traffic + injected faults ----
# One compile_many fleet call precompiles the whole contingency set
# (frontier snap points, deadline-tightened variants, the aggressive
# max-performance point); the adaptive plane then snaps between those
# precompiled points as the arrival rate drifts — never a blocking
# compile on the serving path.
sq_specs = edge_network("squeezenet1.1")
sq_costs = characterize_network(sq_specs, EDGE40NM_DEFAULT)
sq_plan = plan_banks(sq_costs, EDGE40NM_DEFAULT)
UTIL = 0.85                      # provisioning headroom, both sides
bundle = service.compile_contingencies(
    sq_specs, 60.0 / UTIL, tighten_frac=0.92, network="squeezenet1.1")
static_sched = bundle.points[bundle.base_deadline_s]

times = TrafficSimulator(TrafficConfig(
    60.0, scenario="bursty", seed=3, jitter_sigma=0.05,
    burst_rate_mult=1.25, lull_rate_mult=0.4)).frame_times(360)
faults = FaultConfig(seed=7, op_sigma=0.02, trans_sigma=0.1,
                     p_trans_spike=0.02, p_drop=0.01, p_late=0.01,
                     late_max_s=0.003)

static = serve_trace(
    times, StaticSchedulePolicy(static_sched, sq_costs, sq_plan,
                                EDGE40NM_DEFAULT),
    injector=FaultInjector(faults, len(sq_costs)))
plane = AdaptiveScheduler(bundle, sq_costs, sq_plan, EDGE40NM_DEFAULT,
                          service=service, specs=sq_specs)
adaptive = serve_trace(times, plane,
                       injector=FaultInjector(faults, len(sq_costs)))
print("\n[online] bursty traffic, identical fault trace:")
print(f"  static   {static.summary()}")
print(f"  adaptive {adaptive.summary()}")
print(f"  control events: {adaptive.events.kinds()}")

# the adaptive plane holds the service for off-path async recompiles —
# close() drains that pool once serving is done
service.close()
