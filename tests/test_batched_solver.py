"""Batched multi-λ DP engine, pluggable backend, and parallel rail
sweep: equivalence with the scalar/sequential implementations.

The contracts under test (see ISSUE 2 / ROADMAP):
  - ``dp_paths_multi`` rows match per-λ ``dp_best_path`` exactly;
  - the batched λ search selects the same schedule/energy as the legacy
    scalar bisection (``batch_lambda=False``);
  - the jax backend (optional, ``importorskip``) matches the numpy
    backend bit-for-bit on paths and to float tolerance on evaluations,
    including the golden pipeline outputs;
  - the parallel sweep selects the same rails as the sequential sweep
    under out-of-order completion, ties included.
"""

import json
import pathlib

import numpy as np
import pytest

from conftest import max_rate, random_problem
from repro.core import (
    OrchestratorConfig,
    available_backends,
    compile_power_schedule,
    dp_best_path,
    dp_paths_multi,
    dp_paths_multi_weighted,
    get_backend,
    min_time_path,
    select_rails,
    solve_lambda_dp,
)
from repro.models.edge_cnn import edge_network

GOLDEN = json.loads(
    (pathlib.Path(__file__).parent / "golden" / "pipeline.json")
    .read_text())


def _mus(problem):
    return [0.0, -problem.idle.p_sleep, 1e-3, 0.7, 50.0, 1e5]


# ------------------------------------------------- batched DP kernel

@pytest.mark.parametrize("seed", range(8))
def test_dp_multi_rows_match_scalar_dp(seed):
    rng = np.random.default_rng(seed)
    prob = random_problem(rng, n_layers=6, n_states=5)
    mus = _mus(prob)
    multi = dp_paths_multi(prob, mus)
    assert multi.shape == (len(mus), prob.n_layers)
    for j, mu in enumerate(mus):
        assert list(multi[j]) == dp_best_path(prob, mu), mu


def test_dp_multi_weighted_min_time_row():
    rng = np.random.default_rng(3)
    prob = random_problem(rng, n_layers=5, n_states=4)
    row = dp_paths_multi_weighted(prob, [0.0], [1.0])[0]
    assert list(row) == min_time_path(prob)


def test_dp_multi_validates_weights():
    rng = np.random.default_rng(0)
    prob = random_problem(rng, n_layers=3, n_states=3)
    with pytest.raises(ValueError, match="equal-length"):
        dp_paths_multi_weighted(prob, [1.0, 1.0], [0.0])


# --------------------------------------------- batched λ search

@pytest.mark.parametrize("seed", range(10))
def test_batched_search_matches_scalar_bisection(seed):
    """Selected schedule/energy identical between the batched engine
    and the legacy scalar bisection (tight and loose deadlines)."""
    rng = np.random.default_rng(seed)
    scale = 0.9 if seed % 2 else 1.0
    prob = random_problem(rng, n_layers=6, n_states=5,
                          t_max_scale=scale)
    b1, c1, s1 = solve_lambda_dp(prob, batch_lambda=True)
    b2, c2, s2 = solve_lambda_dp(prob, batch_lambda=False)
    assert (b1 is None) == (b2 is None)
    if b1 is None:
        return
    assert b1["e_total"] == pytest.approx(b2["e_total"], rel=1e-9)
    assert b1["feasible"] and b2["feasible"]
    # the engine's whole point: fewer DP invocations
    assert s1.dp_calls < s2.dp_calls


def test_batched_search_warm_hint_converges():
    rng = np.random.default_rng(17)
    prob = random_problem(rng, n_layers=6, n_states=5, t_max_scale=0.9)
    cold, _, sc = solve_lambda_dp(prob, batch_lambda=True)
    if cold is None:
        pytest.skip("instance infeasible")
    warm, _, sw = solve_lambda_dp(prob, batch_lambda=True,
                                  lam_hint=sc.lambda_star)
    assert warm["e_total"] == pytest.approx(cold["e_total"], rel=1e-9)


def test_infeasible_deadline_batched_returns_none():
    rng = np.random.default_rng(33)
    prob = random_problem(rng, n_layers=4, n_states=3, t_max_scale=1e-6)
    best, cands, _ = solve_lambda_dp(prob, batch_lambda=True)
    assert best is None and cands == []


# -------------------------------------------------- input validation

def test_evaluate_paths_rejects_bad_shapes():
    rng = np.random.default_rng(1)
    prob = random_problem(rng, n_layers=4, n_states=3)
    with pytest.raises(ValueError, match="paths must be"):
        prob.evaluate_paths([[0, 0]])                  # wrong L
    with pytest.raises(ValueError, match="out of range"):
        prob.evaluate_paths([[0, 0, 0, 99]])           # bad state index
    with pytest.raises(ValueError, match="entries"):
        prob.evaluate([0, 0])                          # wrong L (scalar)


# ---------------------------------------------------- backend registry

def test_backend_registry():
    assert "numpy" in available_backends()
    bk = get_backend("numpy")
    assert bk.name == "numpy" and not bk.jitted
    assert get_backend(bk) is bk                        # instance pass-through
    with pytest.raises(ValueError, match="unknown backend"):
        get_backend("tpu")


def test_backend_env_default(monkeypatch):
    monkeypatch.setenv("PFDNN_BACKEND", "numpy")
    assert get_backend(None).name == "numpy"


# ------------------------------------------------------- jax backend

jax_only = pytest.mark.skipif("jax" not in available_backends(),
                              reason="jax not installed")


@jax_only
@pytest.mark.parametrize("seed", range(6))
def test_jax_dp_multi_matches_numpy(seed):
    rng = np.random.default_rng(seed)
    prob = random_problem(rng, n_layers=6, n_states=5)
    mus = _mus(prob)
    np.testing.assert_array_equal(
        dp_paths_multi(prob, mus, backend="jax"),
        dp_paths_multi(prob, mus, backend="numpy"))


@jax_only
def test_jax_evaluate_paths_matches_numpy():
    rng = np.random.default_rng(5)
    prob = random_problem(rng, n_layers=6, n_states=5)
    paths = [[int(rng.integers(len(s))) for s in prob.layer_states]
             for _ in range(16)]
    a = prob.evaluate_paths(paths, backend="numpy")
    b = prob.evaluate_paths(paths, backend="jax")
    for key in ("t_infer", "e_op", "e_trans", "t_trans", "e_idle",
                "e_total"):
        np.testing.assert_allclose(a[key], b[key], rtol=1e-12, atol=0)
    np.testing.assert_array_equal(a["n_rail_switches"],
                                  b["n_rail_switches"])
    np.testing.assert_array_equal(a["feasible"], b["feasible"])


@jax_only
def test_jax_backend_reproduces_golden_pipeline():
    """One full compile per policy family on the jitted jax backend —
    outputs must equal the (numpy-produced) golden file."""
    key = "squeezenet1.1|0.9|2|pfdnn"
    golden = GOLDEN[key]
    network, frac, n_rails, policy = key.split("|")
    s = compile_power_schedule(
        edge_network(network), max_rate(network) * float(frac),
        cfg=OrchestratorConfig(policy=policy, n_max_rails=int(n_rails),
                               backend="jax"),
        network=network)
    assert s.e_total == pytest.approx(golden["e_total"], rel=1e-9)
    assert list(s.rails) == golden["rails"]
    assert [list(v) for v in s.layer_voltages] == golden["layer_voltages"]


# ------------------------------------------------------ parallel sweep

def _tie_heavy_solver(record=None):
    """Deterministic per-subset results with deliberate e_total ties, an
    infeasible band (exercises the ceiling), and an incumbent-cuttable
    tail; sleeps perturb completion order."""
    import random
    import time

    rnd = random.Random(0xC0FFEE)

    def solve(subset, hint=None):
        if record is not None:
            record.append(dict(hint or {}))
        time.sleep(rnd.uniform(0.0, 0.004))
        if max(subset) < 1.0:
            return None                      # deadline-infeasible band
        return {"e_total": float(len(subset)),      # ties per size class
                "lambda_star": sum(subset)}

    return solve


def test_parallel_select_rails_matches_serial_with_ties():
    levels = [0.9, 0.95, 1.0, 1.1, 1.2, 1.3]
    bound = lambda s: float(len(s))          # exact → cuts ≥-incumbent
    b_serial, rails_serial, st_serial = select_rails(
        levels, 2, _tie_heavy_solver(), bound_fn=bound)
    for attempt in range(3):                 # vary completion order
        b_par, rails_par, st_par = select_rails(
            levels, 2, _tie_heavy_solver(), bound_fn=bound, workers=4)
        assert rails_par == rails_serial
        assert b_par["e_total"] == b_serial["e_total"]
        assert st_par["workers"] == 4
        assert st_par["subsets_total"] == st_serial["subsets_total"]
        assert (st_par["subsets_solved"] + st_par["subsets_skipped"]
                + st_par["subsets_cut"]) == st_par["subsets_total"]


def test_parallel_sweep_propagates_hints():
    hints: list[dict] = []
    select_rails([0.9, 1.0, 1.1], 2, _tie_heavy_solver(hints), workers=2)
    assert hints and all("lam_hint" in h for h in hints)
    # at least one non-initial solve must have seen a propagated λ*
    assert any(h["lam_hint"] is not None for h in hints[1:])


def test_parallel_pfdnn_compile_matches_serial():
    """End-to-end: the fanned-out pfdnn sweep emits the identical
    schedule as the sequential one."""
    network = "squeezenet1.1"
    specs = edge_network(network)
    rate = max_rate(network) * 0.8
    serial = compile_power_schedule(
        specs, rate, cfg=OrchestratorConfig(policy="pfdnn", n_max_rails=2),
        network=network)
    par = compile_power_schedule(
        specs, rate, cfg=OrchestratorConfig(policy="pfdnn", n_max_rails=2,
                                            sweep_workers=2),
        network=network)
    assert par.rails == serial.rails
    assert par.e_total == pytest.approx(serial.e_total, rel=1e-9)
    assert par.layer_voltages == serial.layer_voltages
    assert par.solver_stats["workers"] == 2
