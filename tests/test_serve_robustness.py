"""Online serving robustness: fault injection, the adaptive control
plane (snap / ladder / async re-solve / watchdog), and the serve_trace
A/B loop."""

import concurrent.futures
import dataclasses

import numpy as np
import pytest

from repro.core import OrchestratorConfig
from repro.hw.edge40nm import EDGE40NM_DEFAULT as ACC
from repro.models.edge_cnn import edge_network
from repro.perfmodel import characterize_network, plan_banks
from repro.serve import (
    AdaptiveConfig,
    AdaptiveScheduler,
    AsyncResolver,
    FaultConfig,
    FaultInjector,
    LedgerMismatch,
    MissLedger,
    PeriodicScheduler,
    PowerRuntime,
    RateTracker,
    StaticSchedulePolicy,
    TrafficConfig,
    TrafficSimulator,
    linear_drift,
    serve_trace,
    simulate_interval,
)
from repro.serve.control_plane import (
    RUNG_AGGRESSIVE,
    RUNG_POINT,
    RUNG_TIGHTENED,
)
from repro.serve.faults import IntervalFaults
from repro.service import CompileService

NETWORK = "squeezenet1.1"
UTIL = 0.85
BASE_RATE = 60.0
GREEDY = OrchestratorConfig(policy="greedy_gating")


@pytest.fixture(scope="module")
def net():
    specs = edge_network(NETWORK)
    costs = characterize_network(specs, ACC)
    plan = plan_banks(costs, ACC)
    return specs, costs, plan


class CountingService(CompileService):
    def __init__(self, *a, **kw):
        super().__init__(*a, **kw)
        self.compile_many_calls = 0

    def compile_many(self, *a, **kw):
        self.compile_many_calls += 1
        return super().compile_many(*a, **kw)


@pytest.fixture(scope="module")
def bundle(net):
    specs, _, _ = net
    svc = CountingService(ACC)
    # greedy policies solve only MinEnergy goals → budget_frac=None
    b = svc.compile_contingencies(
        specs, BASE_RATE / UTIL, tighten_frac=0.92, budget_frac=None,
        cfg=GREEDY, network=NETWORK)
    b._fleet_calls = svc.compile_many_calls
    return b


# ------------------------------------------------------ fault injection

def test_fault_injection_deterministic(net):
    _, costs, _ = net
    cfg = FaultConfig(seed=7, op_sigma=0.05, trans_sigma=0.2,
                      p_trans_spike=0.1, p_drop=0.1, p_late=0.2,
                      late_max_s=0.01)
    a = FaultInjector(cfg, len(costs))
    b = FaultInjector(cfg, len(costs))
    # order-independence: draw interval 7 first on one injector, last
    # on the other — interval(i) is pure in (config, i)
    fa7 = a.interval(7)
    for i in range(7):
        fb = b.interval(i)
        fa = a.interval(i)
        np.testing.assert_array_equal(fa.op_scale, fb.op_scale)
        np.testing.assert_array_equal(fa.trans_scale, fb.trans_scale)
        assert (fa.dropped, fa.late_s) == (fb.dropped, fb.late_s)
    fb7 = b.interval(7)
    np.testing.assert_array_equal(fa7.op_scale, fb7.op_scale)
    assert fa7.late_s == fb7.late_s
    # different seeds draw different perturbations
    other = FaultInjector(dataclasses.replace(cfg, seed=8), len(costs))
    assert not np.array_equal(a.interval(0).op_scale,
                              other.interval(0).op_scale)


def test_fault_bias_composes_with_noise(net):
    _, costs, _ = net
    cfg = FaultConfig(seed=7, op_sigma=0.05)
    plain = FaultInjector(cfg, len(costs))
    drift = FaultInjector(cfg, len(costs),
                          op_bias=linear_drift(0.01))
    np.testing.assert_allclose(drift.interval(50).op_scale,
                               plain.interval(50).op_scale * 1.5)
    # ramp-down after the peak (hysteretic-recovery profiles)
    down = linear_drift(0.1, peak=10)
    assert down(10) == pytest.approx(2.0)
    assert down(15) == pytest.approx(1.5)
    assert down(30) == pytest.approx(1.0)


@pytest.fixture(scope="module")
def sched(net, bundle):
    return bundle.points[bundle.base_deadline_s]


def test_faults_perturb_the_ledger(net, sched):
    _, costs, plan = net
    rt = PowerRuntime(sched, costs, plan, ACC)
    clean = rt.execute_interval()
    L = len(costs)
    slow = rt.execute_interval(faults=IntervalFaults(
        op_scale=np.full(L, 1.3), trans_scale=np.full(L, 2.0)))
    assert slow.t_infer > clean.t_infer
    assert slow.e_exec > clean.e_exec
    # dropped frame: nothing executes, one long idle, cannot miss
    drop = rt.execute_interval(faults=IntervalFaults(
        op_scale=np.ones(L), trans_scale=np.ones(L), dropped=True))
    assert drop.dropped and drop.met_deadline
    assert drop.t_infer == 0.0 and drop.e_exec == 0.0
    assert drop.e_total == drop.e_idle > 0.0
    # a late arrival charges against the interval budget
    late = rt.execute_interval(faults=IntervalFaults(
        op_scale=np.ones(L), trans_scale=np.ones(L),
        late_s=sched.t_max))
    assert late.t_late == sched.t_max and not late.met_deadline


def test_simulate_interval_raises_ledger_mismatch(net, sched):
    _, costs, plan = net
    # fault-free on the native deadline: executed == predicted
    led = simulate_interval(sched, costs, plan, ACC)
    assert led.met_deadline
    # corrupt the runtime's cost model: one layer got 50% more cycles
    bad = list(costs)
    bad[0] = dataclasses.replace(
        bad[0], cycles=tuple(c * 1.5 for c in bad[0].cycles))
    with pytest.raises(LedgerMismatch) as exc:
        simulate_interval(sched, bad, plan, ACC)
    err = exc.value
    assert err.field in ("t_infer", "e_total")
    assert err.network == sched.network
    assert err.rel_err > err.rtol
    assert "mismatch" in str(err)
    # the check can be disabled, and is skipped under injected faults /
    # deadline overrides (divergence is then by design)
    simulate_interval(sched, bad, plan, ACC, check=False)
    simulate_interval(sched, bad, plan, ACC,
                      deadline_s=sched.t_max * 2)


def test_periodic_scheduler_guards(net, sched):
    _, costs, plan = net
    rt = PowerRuntime(sched, costs, plan, ACC)
    with pytest.raises(ValueError):
        PeriodicScheduler(rt, 0.0)
    with pytest.raises(ValueError):
        PeriodicScheduler(rt, -5.0)
    run = PeriodicScheduler(rt, BASE_RATE)
    with pytest.raises(ValueError):
        run.run(-1)
    empty = run.run(0)
    assert empty["intervals"] == 0
    assert empty["total_energy_j"] == 0.0
    assert empty["avg_interval_energy_uj"] == 0.0
    assert empty["avg_power_mw"] == 0.0
    inj = FaultInjector(FaultConfig(seed=1, p_drop=1.0), len(costs))
    full = run.run(10, injector=inj)
    assert full["dropped_frames"] == 10
    assert full["deadline_misses"] == 0


# ------------------------------------------------------ observation

def test_rate_tracker_seeds_from_first_gap():
    tr = RateTracker(100.0)                  # provisioned prior: 100Hz
    assert tr.rate == pytest.approx(100.0)   # before any observation
    tr.observe_gap(1 / 60.0)
    assert tr.ewma == pytest.approx(60.0)    # no decay-from-prior tail
    assert tr.rate == pytest.approx(60.0)


def test_rate_tracker_burst_gating():
    tr = RateTracker(60.0, burst_tolerance=0.15)
    for _ in range(20):
        tr.observe_gap(1 / 60.0)
    # sub-tolerance jitter must NOT drive the estimate (that headroom
    # belongs to util_target)
    for _ in range(3):
        tr.observe_gap(1 / 66.0)             # +10% < tolerance
    assert tr.rate < 66.0 * 0.999
    # a genuine burst overrides the trend within a couple of gaps
    tr.observe_gap(1 / 200.0)
    tr.observe_gap(1 / 200.0)
    assert tr.rate > 150.0


def test_miss_ledger_window_and_clear():
    ml = MissLedger(window=4)
    assert ml.miss_rate() == 0.0 and not ml.full
    for miss in (True, True, False, False):
        ml.record(miss)
    assert ml.full and ml.miss_rate() == pytest.approx(0.5)
    ml.record(False)                          # rolls the oldest miss out
    assert ml.miss_rate() == pytest.approx(0.25)
    ml.clear()
    assert ml.n == 0 and ml.miss_rate() == 0.0


# --------------------------------------------------- async resolver

class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


def test_async_resolver_done_error_timeout():
    clock = FakeClock()
    timeouts = []
    r = AsyncResolver(10.0, clock=clock,
                      on_timeout=lambda: timeouts.append(clock.t))
    assert r.poll() is None and not r.busy

    fut = concurrent.futures.Future()
    r.watch("a", fut)
    assert r.busy
    with pytest.raises(RuntimeError):
        r.watch("b", concurrent.futures.Future())   # one in flight max
    fut.set_result(42)
    assert r.poll() == ("done", "a", 42)
    assert not r.busy

    fut = concurrent.futures.Future()
    fut.set_exception(ValueError("boom"))
    r.watch("b", fut)
    status, tag, payload = r.poll()
    assert status == "error" and tag == "b" and "boom" in payload

    hung = concurrent.futures.Future()
    r.watch("c", hung)
    clock.t = 5.0
    assert r.poll() is None                   # within budget: keep waiting
    clock.t = 11.0
    status, tag, elapsed = r.poll()
    assert status == "timeout" and tag == "c" and elapsed == 11.0
    assert timeouts == [11.0]                 # owner detached the pool
    assert not r.busy                         # abandoned, not blocked


def test_async_resolver_validates_watchdog():
    with pytest.raises(ValueError):
        AsyncResolver(0.0)


# ------------------------------------------------ contingency bundle

def test_contingency_bundle_one_fleet_call(bundle):
    assert bundle._fleet_calls == 1           # ONE compile_many batch
    deadlines = bundle.deadlines()
    # the exact provisioned deadline is a snap point (calm parity), and
    # the aggressive point bounds the grid from below
    base = 1.0 / (BASE_RATE / UTIL)
    assert any(abs(d - base) < 1e-12 for d in deadlines)
    assert bundle.aggressive is not None
    assert deadlines[0] == pytest.approx(bundle.aggressive.t_max)
    # tightened variants really are compiled at tighten_frac × deadline
    assert bundle.tightened
    for d, s in bundle.tightened.items():
        assert d in bundle.points
        assert s.t_max == pytest.approx(0.92 * d)
    assert bundle.budget is None              # budget_frac=None (greedy)


def test_contingency_bundle_validation_and_merge(net, bundle):
    specs, _, _ = net
    svc = CompileService(ACC)
    for bad in (dict(base_rate_hz=0.0), dict(rate_band=(0.0, 2.0)),
                dict(rate_band=(1.5, 2.0)), dict(tighten_frac=1.0)):
        with pytest.raises(ValueError):
            svc.compile_contingencies(
                specs, **{"base_rate_hz": BASE_RATE, **bad},
                cfg=GREEDY, budget_frac=None)
    other = svc.compile_contingencies(
        specs, BASE_RATE * 0.25, n_points=2, budget_frac=None,
        cfg=GREEDY, network=NETWORK)
    merged = dataclasses.replace(
        bundle, points=dict(bundle.points),
        tightened=dict(bundle.tightened),
        infeasible=list(bundle.infeasible))
    before = set(merged.points)
    merged.merge_points(other)
    assert set(merged.points) >= before | set(other.points)


# ------------------------------------------------- adaptive scheduler

def _drive(plane, gap_s, n, start=0, t0=0.0):
    """Feed n on-time intervals at a fixed arrival gap."""
    sched = None
    for k in range(start, start + n):
        sched, _ = plane.pick(k, t0 + k * gap_s, gap_s, 0)
        plane.record(k, miss=False, dropped=False, now=t0 + k * gap_s)
    return sched


def test_adaptive_snaps_under_rate_step(net, bundle):
    _, costs, plan = net
    plane = AdaptiveScheduler(bundle, costs, plan, ACC)
    base = _drive(plane, 1 / BASE_RATE, 30)
    assert base.t_max == pytest.approx(bundle.base_deadline_s)
    # rate steps up 25%: the plane tightens within a few intervals,
    # without any compile (no service attached — precompiled only)
    burst = _drive(plane, 1 / (BASE_RATE * 1.25), 10, start=30)
    assert burst.t_max < bundle.base_deadline_s
    snaps = plane.events.of("snap")
    assert len(snaps) >= 2
    assert all(e.detail["precompiled"] for e in snaps)
    # rate steps back down: the plane relaxes again
    relaxed = _drive(plane, 1 / (BASE_RATE * 0.5), 30, start=40)
    assert relaxed.t_max > bundle.base_deadline_s
    assert plane.events.kinds().get("resolve_start") is None


def test_adaptive_ladder_and_hysteretic_recovery(net, bundle):
    _, costs, plan = net
    acfg = AdaptiveConfig(window=8, breach_min_samples=4,
                          breach_miss_rate=0.5, recover_miss_rate=0.05,
                          dwell_intervals=4)
    plane = AdaptiveScheduler(bundle, costs, plan, ACC, acfg=acfg)
    gap = 1 / BASE_RATE

    k = 0
    def feed(miss, n):
        nonlocal k
        for _ in range(n):
            plane.pick(k, k * gap, gap, 0)
            plane.record(k, miss=miss, dropped=False, now=k * gap)
            k += 1

    assert plane.rung == RUNG_POINT
    feed(miss=True, n=4)                      # dwell + min samples
    assert plane.rung == RUNG_TIGHTENED       # breach → first rung
    sched, _ = plane.pick(k, k * gap, gap, 0)
    assert sched.t_max < bundle.base_deadline_s   # tightened variant
    feed(miss=True, n=4)
    assert plane.rung == RUNG_AGGRESSIVE      # still breaching → top rung
    feed(miss=True, n=20)
    assert plane.rung == RUNG_AGGRESSIVE      # ladder is bounded
    # hysteresis: recovery needs a FULL clean window after the dwell —
    # strictly more evidence than the breach needed
    feed(miss=False, n=7)
    assert plane.rung == RUNG_AGGRESSIVE
    feed(miss=False, n=1)
    assert plane.rung == RUNG_TIGHTENED
    feed(miss=False, n=8)
    assert plane.rung == RUNG_POINT
    kinds = plane.events.kinds()
    assert kinds["degrade"] == 2 and kinds["recover"] == 2
    # dropped frames carry no deadline signal
    plane.record(k, miss=True, dropped=True, now=k * gap)
    assert plane.misses.n == 0 or plane.rung == RUNG_POINT


class FakeResolveService:
    """Duck-typed CompileService for the re-solve path: hands back a
    controllable Future and records the watchdog's pool abandonment."""

    def __init__(self):
        self.future = concurrent.futures.Future()
        self.requests = []
        self.abandoned = 0

    def compile_contingencies_async(self, specs, rate_hz, **kw):
        self.requests.append((rate_hz, kw))
        return self.future

    def abandon_async_pool(self):
        self.abandoned += 1


def test_adaptive_resolve_merge_and_watchdog(net, bundle):
    specs, costs, plan = net
    clock = FakeClock()
    acfg = AdaptiveConfig(drift_patience=3, watchdog_s=5.0)
    svc = FakeResolveService()
    merged = dataclasses.replace(
        bundle, points=dict(bundle.points),
        tightened=dict(bundle.tightened),
        infeasible=list(bundle.infeasible))
    plane = AdaptiveScheduler(merged, costs, plan, ACC, service=svc,
                              specs=specs, acfg=acfg, clock=clock)
    # sustained drift far beyond the precompiled coverage (rate ~2Hz)
    slow_gap = 0.5
    for k in range(4):
        plane.pick(k, k * slow_gap, slow_gap, 0)
    assert len(svc.requests) == 1             # re-solve submitted once
    assert plane.events.of("resolve_start")
    assert plane.resolver.busy

    # background solve lands: points merge into the live bundle
    extra = CompileService(ACC).compile_contingencies(
        specs, 2.0, n_points=2, budget_frac=None, cfg=GREEDY,
        network=NETWORK)
    svc.future.set_result(extra)
    plane.pick(4, 4 * slow_gap, slow_gap, 0)
    done = plane.events.of("resolve_done")
    assert done and done[0].detail["new_points"] > 0
    assert max(plane.bundle.points) > bundle.base_deadline_s
    assert max(plane._grid) == max(plane.bundle.points)

    # next sustained drift: this solve hangs → watchdog abandons it
    svc.future = concurrent.futures.Future()
    for k in range(5, 30):
        plane.pick(k, k * slow_gap * 40, slow_gap * 40, 0)
        if len(svc.requests) == 2:
            break
    assert len(svc.requests) == 2
    clock.t += 6.0                            # past the watchdog budget
    plane.pick(50, 0.0, slow_gap, 0)
    assert plane.events.of("resolve_timeout")
    assert svc.abandoned == 1                 # pool detached, not joined
    assert not plane.resolver.busy            # serving never blocked


def test_adaptive_config_validation():
    with pytest.raises(ValueError):
        AdaptiveConfig(util_target=0.0)
    with pytest.raises(ValueError):
        AdaptiveConfig(util_target=1.2)
    with pytest.raises(ValueError):
        AdaptiveConfig(breach_miss_rate=0.2, recover_miss_rate=0.3)


# ------------------------------------------------------- serve_trace

def test_serve_trace_calm_parity(net, bundle, sched):
    _, costs, plan = net
    times = TrafficSimulator(
        TrafficConfig(BASE_RATE, scenario="calm")).frame_times(80)
    static = serve_trace(
        times, StaticSchedulePolicy(sched, costs, plan, ACC))
    adaptive = serve_trace(
        times, AdaptiveScheduler(bundle, costs, plan, ACC))
    assert static.misses == adaptive.misses == 0
    assert adaptive.energy_j == pytest.approx(static.energy_j,
                                              rel=1e-9)
    assert static.energy_j == pytest.approx(
        static.e_exec_j + static.e_idle_j)
    assert static.frames == static.served + static.dropped
    snaps = adaptive.events.of("snap")
    assert len(snaps) == 1 and snaps[0].detail["precompiled"]


def test_serve_trace_fault_accounting(net, bundle, sched):
    _, costs, plan = net
    times = TrafficSimulator(
        TrafficConfig(BASE_RATE, scenario="calm")).frame_times(40)
    all_dropped = serve_trace(
        times, StaticSchedulePolicy(sched, costs, plan, ACC),
        injector=FaultInjector(FaultConfig(seed=1, p_drop=1.0),
                               len(costs)))
    assert all_dropped.served == 0 and all_dropped.dropped == 40
    assert all_dropped.e_exec_j == 0.0
    assert all_dropped.miss_rate == 0.0
    with pytest.raises(ValueError):
        serve_trace(np.array([0.0]),
                    StaticSchedulePolicy(sched, costs, plan, ACC))


def test_traffic_simulator_seeded_and_validated():
    cfg = TrafficConfig(BASE_RATE, scenario="bursty", seed=5,
                        jitter_sigma=0.1)
    t1 = TrafficSimulator(cfg).frame_times(100)
    t2 = TrafficSimulator(cfg).frame_times(100)
    np.testing.assert_array_equal(t1, t2)     # schedule-independent
    assert len(t1) == 101                     # n frames need n+1 stamps
    assert np.all(np.diff(t1) > 0)
    other = TrafficSimulator(
        dataclasses.replace(cfg, seed=6)).frame_times(100)
    assert not np.array_equal(t1, other)
    with pytest.raises(ValueError):
        TrafficConfig(BASE_RATE, scenario="nope")
    with pytest.raises(ValueError):
        TrafficConfig(0.0)
    with pytest.raises(ValueError):
        TrafficConfig(BASE_RATE, diurnal_depth=1.5)
