"""Pallas DP-kernel parity and device-resident lane tests.

The jax backend's Pallas mode (``PFDNN_PALLAS`` /
``OrchestratorConfig.pallas``) replaces the ``vmap(lax.scan)`` inner
reductions of the stacked solver calls with fused argmin-gather Pallas
kernels (``repro.kernels.dp_sweep``), and the lanes API keeps every
admitted rail subset's padded tensors resident on device.  Everything
here pins the mode to the numpy backend bit-for-bit:

  - every pipeline golden compiles identically under
    ``pallas="interpret"`` (the CPU-correctness vehicle of the TPU
    kernels);
  - the kernels match both the numpy backend and the jitted lax.scan
    path at the call level, including first-occurrence argmin
    tie-breaking and padded tail lanes;
  - a hypothesis property sweeps random level sets / μ grids;
  - warm sweep rounds move ZERO operand bytes host→device (the
    transfer counters only tick when a lane is first admitted);
  - lane padding is monotonic per store, so shrink-then-regrow round
    widths never recompile.
"""

import json
import pathlib

import numpy as np
import pytest

from conftest import max_rate, random_problem
from repro.core import (
    OrchestratorConfig,
    StackedLambdaTask,
    compile_power_schedule,
    get_backend,
    select_rails_stacked,
)
from repro.core.backend import (
    BucketStack,
    PendingResult,
    StackCaches,
    build_padded,
    repad,
    stack_padded,
)
from repro.core.lambda_dp import kbest_rows_to_lists
from repro.models.edge_cnn import edge_network

GOLDEN = json.loads(
    (pathlib.Path(__file__).parent / "golden" / "pipeline.json")
    .read_text())

PALLAS = "jax-pallas-interpret"

_RATES: dict[tuple[str, str], float] = {}


def _rate(network: str, frac: str) -> float:
    key = (network, frac)
    if key not in _RATES:
        _RATES[key] = max_rate(network) * float(frac)
    return _RATES[key]


# ------------------------------------------------ golden bit-identity

@pytest.mark.parametrize("key", sorted(GOLDEN))
def test_golden_compiles_bit_identical_under_pallas(key):
    """Every policy × config of the pipeline goldens, compiled with the
    Pallas interpret backend, reproduces the frozen numpy outputs —
    rails and voltage paths exactly, scalars to float tolerance."""
    network, frac, n_rails, policy = key.split("|")
    golden = GOLDEN[key]
    s = compile_power_schedule(
        edge_network(network), _rate(network, frac),
        cfg=OrchestratorConfig(policy=policy, n_max_rails=int(n_rails),
                               backend="jax", pallas="interpret"),
        network=network)
    if not golden["feasible"]:
        assert s is None
        return
    assert s is not None
    assert s.e_total == pytest.approx(golden["e_total"], rel=1e-9)
    assert s.t_infer == pytest.approx(golden["t_infer"], rel=1e-9)
    assert list(s.rails) == golden["rails"]
    assert [list(v) for v in s.layer_voltages] == golden["layer_voltages"]


# ------------------------------------------- kernel-level parity

def _stack_from(problems):
    padded = [build_padded(p) for p in problems]
    sp = max(p.s_pad for p in padded)
    return stack_padded([repad(p, sp) for p in padded])


def test_pallas_stacked_matches_scan_and_numpy(monkeypatch, rng):
    """The three Pallas kernels against BOTH references on one stack:
    the numpy backend and the jitted lax.scan path (thresholds forced
    to zero so the CPU heuristics cannot route either to the host)."""
    pk = get_backend(PALLAS)
    # "jax" routes to the pallas instance while $PFDNN_PALLAS is set —
    # clear it so jx really is the plain lax.scan backend
    monkeypatch.delenv("PFDNN_PALLAS", raising=False)
    jx = get_backend("jax")
    ref = get_backend("numpy")
    assert pk is not jx and pk.pallas_mode == "interpret"
    monkeypatch.setattr(type(jx), "_JIT_MIN_WORK", 0)
    monkeypatch.setattr(type(jx), "_KBEST_JIT_MIN_WORK", 0)
    problems = [random_problem(rng, n_layers=5, n_states=n)
                for n in (4, 6, 3)]
    stack = _stack_from(problems)
    w_e = rng.random((3, 5))
    w_t = rng.random((3, 5))
    mus = rng.random((3, 3)) * 10.0
    for other in (ref, jx):
        np.testing.assert_array_equal(
            pk.dp_multi_stacked(stack, w_e, w_t),
            other.dp_multi_stacked(stack, w_e, w_t))
        pp, pc = pk.kbest_multi_stacked(stack, mus, 4)
        op, oc = other.kbest_multi_stacked(stack, mus, 4)
        np.testing.assert_array_equal(pc, oc)
        for b in range(3):
            assert kbest_rows_to_lists(pp[b], pc[b]) == \
                kbest_rows_to_lists(op[b], oc[b])
        lanes = np.array([0, 1, 2, 2, 0], dtype=np.int64)
        paths = np.stack([np.asarray(
            pk.dp_multi_stacked(stack, w_e, w_t)[b, 0])
            for b in lanes])
        got = pk.path_costs_stacked(stack, lanes, paths)
        exp = other.path_costs_stacked(stack, lanes, paths)
        for k in exp:
            np.testing.assert_array_equal(got[k], exp[k], err_msg=k)


def test_pallas_single_layer_stack_matches_numpy(rng):
    """L == 1 takes the pure-jnp special case of the jitted wrappers
    (no transition axis for a kernel to reduce) — still bit-exact."""
    pk = get_backend(PALLAS)
    ref = get_backend("numpy")
    problems = [random_problem(rng, n_layers=1, n_states=4)
                for _ in range(2)]
    stack = _stack_from(problems)
    w = rng.random((2, 3))
    np.testing.assert_array_equal(
        pk.dp_multi_stacked(stack, w, w[:, ::-1]),
        ref.dp_multi_stacked(stack, w, w[:, ::-1]))
    pp, pc = pk.kbest_multi_stacked(stack, w[:, :2], 3)
    op, oc = ref.kbest_multi_stacked(stack, w[:, :2], 3)
    np.testing.assert_array_equal(pc, oc)
    np.testing.assert_array_equal(pp[pc > 0], op[oc > 0])


def test_pallas_ties_break_first_occurrence(rng):
    """Duplicate states tie path costs bitwise; the kernels must pick
    the same (first-occurrence) argmin / stable-sort order as numpy —
    paths compared EXACTLY, not just their costs."""
    problems = []
    for _ in range(3):
        p = random_problem(rng, n_layers=4, n_states=5)
        for states in p.layer_states:
            states[1] = states[0]       # exact duplicate per layer
            states[4] = states[3]
        problems.append(p)
    stack = _stack_from(problems)
    pk = get_backend(PALLAS)
    ref = get_backend("numpy")
    w_e = rng.random((3, 4))
    w_t = rng.random((3, 4))
    np.testing.assert_array_equal(
        pk.dp_multi_stacked(stack, w_e, w_t),
        ref.dp_multi_stacked(stack, w_e, w_t))
    pp, pc = pk.kbest_multi_stacked(stack, w_e[:, :2], 6)
    op, oc = ref.kbest_multi_stacked(stack, w_e[:, :2], 6)
    np.testing.assert_array_equal(pc, oc)
    for b in range(3):
        assert kbest_rows_to_lists(pp[b], pc[b]) == \
            kbest_rows_to_lists(op[b], oc[b])


def test_pallas_padded_tail_lanes_are_dropped(rng):
    """Lane counts off the power-of-two bucket (and widened by the
    monotonic pad hint) are padded with repeats of lane 0; the result
    rows of the real lanes must be untouched by the padding."""
    pk = get_backend(PALLAS)
    ref = get_backend("numpy")
    problems = [random_problem(rng, n_layers=3, n_states=4)
                for _ in range(3)]                  # 3 lanes → pad to 4+
    stack = _stack_from(problems)
    stack.dev_cache["lane_pad_hint"] = 8            # force a wide pad
    w = rng.random((3, 2))
    np.testing.assert_array_equal(
        pk.dp_multi_stacked(stack, w, w + 1.0),
        ref.dp_multi_stacked(stack, w, w + 1.0))


def test_property_pallas_matches_numpy_random_level_sets():
    """Hypothesis property: random level sets and μ grids at one fixed
    padded shape (so the suite compiles each kernel once) — DP paths
    and the k-best frontier match the numpy backend exactly."""
    pytest.importorskip(
        "hypothesis",
        reason="hypothesis not installed (see requirements-dev.txt)")
    from hypothesis import given, settings, strategies as hst

    pk = get_backend(PALLAS)
    ref = get_backend("numpy")

    @settings(max_examples=12, deadline=None)
    @given(seed=hst.integers(0, 2**32 - 1),
           k=hst.integers(min_value=1, max_value=4))
    def prop(seed, k):
        r = np.random.default_rng(seed)
        problems = [random_problem(r, n_layers=3, n_states=4)
                    for _ in range(2)]
        stack = _stack_from(problems)
        w_e = r.random((2, 4))
        w_t = r.random((2, 4))
        mus = np.concatenate(
            [[0.0], np.sort(r.random(3)) * 50.0])[None, :].repeat(
                2, axis=0)
        np.testing.assert_array_equal(
            pk.dp_multi_stacked(stack, w_e, w_t),
            ref.dp_multi_stacked(stack, w_e, w_t))
        pp, pc = pk.kbest_multi_stacked(stack, mus, k)
        op, oc = ref.kbest_multi_stacked(stack, mus, k)
        np.testing.assert_array_equal(pc, oc)
        for b in range(2):
            assert kbest_rows_to_lists(pp[b], pc[b]) == \
                kbest_rows_to_lists(op[b], oc[b])

    prop()


# ------------------------------------- device-resident lane stores

def _lane_store(rng, n=3, n_layers=4, n_states=5):
    pads = [build_padded(random_problem(rng, n_layers=n_layers,
                                        n_states=n_states))
            for _ in range(n)]
    sp = max(p.s_pad for p in pads)
    pads = [repad(p, sp) for p in pads]
    store = BucketStack(pads[0].n_layers, sp)
    lanes = [store.add(("lane", i), p) for i, p in enumerate(pads)]
    return store, lanes


def test_lanes_api_matches_member_stack_and_counts_uploads(rng):
    """The lanes entry points equal the member-stack entry points lane
    for lane, each lane's tensors go host→device exactly ONCE, and
    warm repeats upload nothing."""
    pk = get_backend(PALLAS)
    ref = get_backend("numpy")
    store, lanes = _lane_store(rng)
    base = dict(pk.io_stats)
    w_e = rng.random((3, 4))
    w_t = rng.random((3, 4))
    mus = rng.random((3, 2))
    got = pk.dp_multi_lanes(store, lanes, w_e, w_t)
    exp = ref.dp_multi_stacked(pk._host_member_stack(store, lanes),
                               w_e, w_t)
    np.testing.assert_array_equal(got, exp)
    gp, gc = pk.kbest_multi_lanes(store, lanes, mus, 4)
    ep, ec = ref.kbest_multi_stacked(pk._host_member_stack(store, lanes),
                                     mus, 4)
    np.testing.assert_array_equal(gc, ec)
    for b in range(3):
        assert kbest_rows_to_lists(gp[b], gc[b]) == \
            kbest_rows_to_lists(ep[b], ec[b])
    pl = np.asarray([0, 2, 1, 1], dtype=np.int64)
    pp_ = rng.integers(0, 5, (4, 4)).astype(np.int64)
    gotc = pk.path_costs_lanes(store, pl, pp_)
    expc = ref.path_costs_stacked(store.view(), pl, pp_)
    for k in expc:
        np.testing.assert_array_equal(gotc[k], expc[k], err_msg=k)
    cold = pk.io_stats["h2d_lane_uploads"] - base["h2d_lane_uploads"]
    assert cold == len(lanes)
    assert pk.io_stats["h2d_lane_bytes"] > base["h2d_lane_bytes"]
    # warm repeats: zero further operand uploads, dispatches still tick
    mark = dict(pk.io_stats)
    pk.dp_multi_lanes(store, lanes, w_e, w_t)
    pk.kbest_multi_lanes(store, lanes, mus, 4)
    pk.path_costs_lanes(store, pl, pp_)
    assert pk.io_stats["h2d_lane_uploads"] == mark["h2d_lane_uploads"]
    assert pk.io_stats["h2d_lane_bytes"] == mark["h2d_lane_bytes"]
    assert pk.io_stats["kernel_dispatches"] >= \
        mark["kernel_dispatches"] + 3


def test_lane_admission_uploads_only_the_new_lane(rng):
    """Growing a warm store re-uses the resident mirror: admitting one
    more lane uploads exactly that lane."""
    pk = get_backend(PALLAS)
    store, lanes = _lane_store(rng)
    w = np.ones((len(lanes), 2))
    pk.dp_multi_lanes(store, lanes, w, w)
    mark = pk.io_stats["h2d_lane_uploads"]
    extra = repad(build_padded(random_problem(
        rng, n_layers=store._t_op.shape[1],
        n_states=4)), store._t_op.shape[2])
    lanes.append(store.add(("lane", "extra"), extra))
    w = np.ones((len(lanes), 2))
    pk.dp_multi_lanes(store, lanes, w, w)
    assert pk.io_stats["h2d_lane_uploads"] == mark + 1


def test_warm_sweep_rounds_upload_nothing(monkeypatch, rng):
    """End-to-end transfer counting through the round scheduler: a
    second full sweep on the same persistent lane stores (the service
    steady state) runs entirely from the device mirrors."""
    from test_stacked_sweep import _MasterInstance
    from repro.core.rails import all_rail_subsets

    bk = get_backend(PALLAS)
    inst = _MasterInstance(1, n_layers=4, n_levels=4,
                           thresh_frac=0.3, tie_energies=False)

    def make_task(idx, subset, hint=None):
        # a content-derived lane key is what lets the persistent
        # stores recognize the subset across sweeps (the fleet
        # service derives one from the problem content)
        return StackedLambdaTask(idx, subset, inst.problem(subset),
                                 lane_key=("subset", subset),
                                 caches=caches)

    caches = StackCaches()
    ref = select_rails_stacked(
        all_rail_subsets(inst.levels, 3), make_task, max_live=8)
    cold = select_rails_stacked(
        all_rail_subsets(inst.levels, 3), make_task, max_live=8,
        backend=PALLAS, caches=caches)
    mark = dict(bk.io_stats)
    warm = select_rails_stacked(
        all_rail_subsets(inst.levels, 3), make_task, max_live=8,
        backend=PALLAS, caches=caches)
    assert bk.io_stats["h2d_lane_uploads"] == mark["h2d_lane_uploads"]
    assert bk.io_stats["h2d_lane_bytes"] == mark["h2d_lane_bytes"]
    # and all three sweeps selected identically
    for got in (cold, warm):
        assert got[1] == ref[1]
        if ref[0] is not None:
            assert got[0]["e_total"] == ref[0]["e_total"]
            assert got[0]["path"] == ref[0]["path"]


def test_lane_pad_is_monotonic_per_store():
    store = BucketStack(2, 3)
    assert store.lane_pad_for(3) == 4
    assert store.lane_pad_for(2) == 4      # never shrinks
    assert store.lane_pad_for(5) == 8
    assert store.lane_pad_for(1) == 8


def test_pending_result_defers_and_memoizes():
    calls = []

    def fn():
        calls.append(1)
        return 42

    pend = PendingResult(fn)
    assert not calls                       # nothing ran at dispatch
    assert pend.get() == 42
    assert pend.get() == 42
    assert len(calls) == 1                 # collected exactly once
    assert PendingResult.ready("x").get() == "x"


# ---------------------------------------- configuration / routing

def test_orchestrator_config_pallas_validation():
    cfg = OrchestratorConfig(backend="jax", pallas="interpret")
    assert cfg.backend == "jax-pallas-interpret"
    cfg = OrchestratorConfig(pallas="device")
    assert cfg.backend == "jax-pallas"
    with pytest.raises(ValueError, match="pallas"):
        OrchestratorConfig(pallas="nope")
    with pytest.raises(ValueError, match="numpy"):
        OrchestratorConfig(backend="numpy", pallas="interpret")


def test_pallas_env_var_routes_the_jax_backend(monkeypatch):
    monkeypatch.setenv("PFDNN_PALLAS", "interpret")
    assert get_backend("jax") is get_backend(PALLAS)
    monkeypatch.setenv("PFDNN_PALLAS", "off")
    assert get_backend("jax") is not get_backend(PALLAS)
    monkeypatch.setenv("PFDNN_PALLAS", "bogus")
    with pytest.raises(ValueError, match="PFDNN_PALLAS"):
        get_backend("jax")


def test_pallas_backend_is_cached_and_named(monkeypatch):
    pk = get_backend(PALLAS)
    assert pk is get_backend(PALLAS)
    assert pk.name == "jax"                # stats/golden compatibility
    assert pk.pallas_mode == "interpret"
    monkeypatch.delenv("PFDNN_PALLAS", raising=False)
    assert get_backend("jax").pallas_mode is None
