"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in
interpret=True mode (kernel bodies execute on CPU)."""

import jax
import jax.numpy as jnp
import pytest

from repro.kernels.flash_attention import flash_attention
from repro.kernels.flash_decode import flash_decode
from repro.kernels.int8_matmul import int8_matmul
from repro.kernels.ops import attention_bshd, int8_linear, quantize_int8
from repro.kernels.ref import (
    flash_attention_ref,
    flash_decode_ref,
    int8_matmul_ref,
)

KEY = jax.random.PRNGKey(0)


@pytest.mark.parametrize("m,k,n,bm,bn,bk", [
    (128, 128, 128, 128, 128, 128),
    (256, 512, 128, 128, 128, 128),
    (64, 64, 192, 32, 64, 32),
    (32, 96, 32, 16, 16, 16),
])
def test_int8_matmul_sweep(m, k, n, bm, bn, bk):
    k1, k2, k3, k4 = jax.random.split(KEY, 4)
    x = jax.random.randint(k1, (m, k), -128, 127, jnp.int8)
    w = jax.random.randint(k2, (k, n), -128, 127, jnp.int8)
    xs = jax.random.uniform(k3, (m,), jnp.float32, 0.5, 2.0)
    ws = jax.random.uniform(k4, (n,), jnp.float32, 0.5, 2.0)
    out = int8_matmul(x, w, xs, ws, bm=bm, bn=bn, bk=bk, interpret=True)
    ref = int8_matmul_ref(x, w, xs, ws)
    assert jnp.allclose(out, ref, rtol=1e-6, atol=1e-4)


@pytest.mark.parametrize("b,h,kh,sq,sk,d,bq,bk,causal,dtype", [
    (2, 4, 4, 128, 128, 64, 64, 64, True, jnp.float32),
    (1, 8, 2, 64, 128, 32, 32, 32, True, jnp.float32),
    (2, 4, 1, 128, 256, 32, 64, 128, False, jnp.float32),
    (1, 2, 2, 256, 256, 128, 128, 64, True, jnp.bfloat16),
    (1, 4, 2, 64, 64, 16, 16, 16, True, jnp.float32),
])
def test_flash_attention_sweep(b, h, kh, sq, sk, d, bq, bk, causal,
                               dtype):
    k1, k2, k3 = jax.random.split(KEY, 3)
    q = jax.random.normal(k1, (b, h, sq, d), dtype)
    k = jax.random.normal(k2, (b, kh, sk, d), dtype)
    v = jax.random.normal(k3, (b, kh, sk, d), dtype)
    out = flash_attention(q, k, v, causal=causal, bq=bq, bk=bk,
                          q_offset=(sk - sq) if causal else 0,
                          interpret=True)
    kr = jnp.repeat(k, h // kh, axis=1)
    vr = jnp.repeat(v, h // kh, axis=1)
    ref = flash_attention_ref(q.astype(jnp.float32),
                              kr.astype(jnp.float32),
                              vr.astype(jnp.float32), causal=causal)
    tol = 3e-5 if dtype == jnp.float32 else 2e-2
    assert jnp.max(jnp.abs(out.astype(jnp.float32) - ref)) < tol


@pytest.mark.parametrize("b,h,kh,s,d,bs,dtype", [
    (2, 4, 4, 256, 64, 64, jnp.float32),
    (3, 8, 2, 128, 32, 32, jnp.float32),
    (1, 4, 1, 512, 128, 128, jnp.bfloat16),
])
def test_flash_decode_sweep(b, h, kh, s, d, bs, dtype):
    k1, k2, k3, k4 = jax.random.split(KEY, 4)
    q = jax.random.normal(k1, (b, h, d), dtype)
    k = jax.random.normal(k2, (b, s, kh, d), dtype)
    v = jax.random.normal(k3, (b, s, kh, d), dtype)
    lens = jax.random.randint(k4, (b,), 1, s + 1, jnp.int32)
    out = flash_decode(q, k, v, lens, bs=bs, interpret=True)
    kr = jnp.repeat(k, h // kh, axis=2)
    vr = jnp.repeat(v, h // kh, axis=2)
    ref = flash_decode_ref(q.astype(jnp.float32),
                           kr.astype(jnp.float32),
                           vr.astype(jnp.float32), lens)
    tol = 3e-5 if dtype == jnp.float32 else 2e-2
    assert jnp.max(jnp.abs(out.astype(jnp.float32) - ref)) < tol


def test_pallas_matches_model_zoo_attention():
    """The fused kernel is a drop-in for the jnp path used by models."""
    from repro.models.layers import AttnChunks, flash_attention_jnp

    k1, k2, k3 = jax.random.split(KEY, 3)
    q = jax.random.normal(k1, (2, 128, 8, 64), jnp.float32)
    k = jax.random.normal(k2, (2, 128, 2, 64), jnp.float32)
    v = jax.random.normal(k3, (2, 128, 2, 64), jnp.float32)
    o_pallas = attention_bshd(q, k, v, causal=True, interpret=True)
    o_jnp = flash_attention_jnp(q, k, v, causal=True,
                                chunks=AttnChunks(32, 32))
    assert jnp.max(jnp.abs(o_pallas - o_jnp)) < 3e-5


def test_int8_linear_quantization_error_bounded():
    k1, k2 = jax.random.split(KEY)
    x = jax.random.normal(k1, (64, 256))
    w = jax.random.normal(k2, (256, 128))
    out = int8_linear(x, w, interpret=True)
    ref = x @ w
    rel = jnp.max(jnp.abs(out - ref)) / jnp.max(jnp.abs(ref))
    assert rel < 0.05     # int8 quantization noise budget


def test_quantize_int8_roundtrip():
    x = jax.random.normal(KEY, (16, 64)) * 3
    q, s = quantize_int8(x, axis=1)
    deq = q.astype(jnp.float32) * s[:, None]
    assert jnp.max(jnp.abs(deq - x)) <= jnp.max(jnp.abs(x)) / 127 + 1e-6
    assert q.dtype == jnp.int8
