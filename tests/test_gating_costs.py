"""Edge cases of the perf model: bank-gating plans for weightless and
gating-disabled paths, per-kind cycle formulas incl. zero-cost layers,
and single-voltage tables."""

import dataclasses

import pytest

from repro.core.goals import MinEnergy
from repro.core.orchestrator import compile as pfdnn_compile
from repro.hw.dvfs import V_GATED, DvfsModel, voltage_levels
from repro.hw.edge40nm import (
    D_COMPUTE,
    D_FEEDER,
    D_RRAM,
    EDGE40NM_DEFAULT as ACC,
    Edge40nmAccelerator,
)
from repro.perfmodel.gating import plan_banks
from repro.perfmodel.layer_costs import (
    attention_spec,
    characterize_layer,
    characterize_network,
    conv_spec,
    dwconv_spec,
    eltwise_spec,
    fc_spec,
    nominal_latency,
    pool_spec,
)


# --------------------------------------------------------- bank plans

class TestBankPlan:
    def test_weightless_layers_get_sentinel_span(self):
        specs = [conv_spec("c", 8, 8, 8, 8, 3),
                 pool_spec("p", 8, 8, 8, 2),
                 eltwise_spec("e", 4, 4, 8)]
        plan = plan_banks(characterize_network(specs, ACC), ACC)
        assert plan.spans[1] == (-1, -1)
        assert plan.spans[2] == (-1, -1)
        assert plan.spans[0][0] == 0

    def test_all_weightless_network_keeps_one_bank(self):
        specs = [pool_spec("p", 8, 8, 8, 2), eltwise_spec("e", 4, 4, 8)]
        plan = plan_banks(characterize_network(specs, ACC), ACC)
        assert plan.n_banks == 1
        assert plan.spans == ((-1, -1), (-1, -1))
        # pg_manager bank is always on, even with nothing to fetch
        assert plan.awake_banks(0, gating=True) == 1
        assert plan.wake_events(0, gating=True) == 0

    def test_gating_disabled_wakes_everything(self):
        specs = [fc_spec("f1", 512, 512), fc_spec("f2", 512, 512)]
        plan = plan_banks(characterize_network(specs, ACC), ACC)
        assert plan.n_banks > 1
        for i in range(len(specs)):
            assert plan.awake_banks(i, gating=False) == plan.n_banks
            assert plan.wake_events(i, gating=False) == 0

    def test_prefetch_includes_next_layer(self):
        specs = [fc_spec("f1", 512, 512), fc_spec("f2", 512, 512)]
        plan = plan_banks(characterize_network(specs, ACC), ACC)
        lo0, hi0 = plan.spans[0]
        lo1, hi1 = plan.spans[1]
        both = len(set(range(lo0, hi0 + 1)) | set(range(lo1, hi1 + 1)))
        assert plan.awake_banks(0, gating=True) == both
        assert plan.awake_banks(0, gating=True, prefetch=False) == (
            hi0 - lo0 + 1)
        # last layer has no successor to prefetch
        assert plan.awake_banks(1, gating=True) == hi1 - lo1 + 1
        assert plan.wake_events(1, gating=True) == 0

    def test_wake_events_skip_already_awake_banks(self):
        # two layers sharing one bank: prefetching layer 1 during
        # layer 0 wakes nothing new
        specs = [fc_spec("f1", 16, 16), fc_spec("f2", 16, 16)]
        plan = plan_banks(characterize_network(specs, ACC), ACC)
        assert plan.spans[0] == plan.spans[1] == (0, 0)
        assert plan.wake_events(0, gating=True) == 0

    def test_wake_events_weightless_successor(self):
        specs = [fc_spec("f", 512, 512), pool_spec("p", 8, 8, 8, 2)]
        plan = plan_banks(characterize_network(specs, ACC), ACC)
        assert plan.wake_events(0, gating=True) == 0

    def test_span_straddles_bank_boundary(self):
        bank = ACC.rram_bank_bytes
        specs = [fc_spec("f1", bank // 32, 16),    # exactly half a bank
                 fc_spec("f2", bank // 16, 16)]    # one full bank
        plan = plan_banks(characterize_network(specs, ACC), ACC)
        assert plan.spans[0] == (0, 0)
        assert plan.spans[1] == (0, 1)   # starts mid-bank, spills over
        assert plan.n_banks == 2


# --------------------------------------------------------- layer costs

class TestLayerCosts:
    def test_zero_cost_layers_have_no_compute_energy(self):
        for spec in (pool_spec("p", 8, 8, 16, 2),
                     eltwise_spec("e", 8, 8, 16)):
            cost = characterize_layer(spec, ACC)
            assert spec.macs == 0 and spec.weight_bytes == 0
            assert cost.cycles[D_RRAM] == 0
            assert cost.dyn_energy_nom[D_RRAM] == 0.0
            assert cost.cycles[D_COMPUTE] > 0      # ALU work remains
            assert cost.dyn_energy_nom[D_FEEDER] > 0.0
            # latency stays finite with a zero-cycle domain in the max
            assert nominal_latency(cost, ACC) > 0.0

    def test_conv_cycle_formula(self):
        spec = conv_spec("c", 14, 14, 16, 32, 3)
        cost = characterize_layer(spec, ACC)
        p_tiles = -(-spec.p_out // ACC.pe_rows)
        c_tiles = -(-spec.c_out // ACC.pe_cols)
        assert cost.cycles[D_COMPUTE] == p_tiles * c_tiles * 16 * 9
        moved = (spec.act_in_bytes + spec.act_out_bytes
                 + spec.weight_bytes)
        assert cost.cycles[D_FEEDER] == -(-moved // 8)
        assert cost.cycles[D_RRAM] == -(-spec.weight_bytes // 8)

    def test_dwconv_drops_cin_factor(self):
        dw = characterize_layer(dwconv_spec("d", 14, 14, 64, 3), ACC)
        full = characterize_layer(conv_spec("c", 14, 14, 64, 64, 3), ACC)
        assert full.cycles[D_COMPUTE] == 64 * dw.cycles[D_COMPUTE]

    def test_fc_is_rram_dominant(self):
        cost = characterize_layer(fc_spec("f", 1024, 1024), ACC)
        assert cost.dyn_energy_nom[D_RRAM] == max(cost.dyn_energy_nom)

    def test_attn_overhead_factor(self):
        spec = attention_spec("a", 16, 64, 4, d_ff=128)
        cost = characterize_layer(spec, ACC)
        assert cost.cycles[D_COMPUTE] == int(spec.macs / 64 * 1.15) + 1

    def test_single_output_fc(self):
        cost = characterize_layer(fc_spec("f", 8, 1), ACC)
        assert cost.cycles[D_COMPUTE] == ACC.pe_rows
        assert nominal_latency(cost, ACC) > 0.0


# ----------------------------------------------- single-voltage tables

class TestSingleVoltage:
    def test_degenerate_level_table(self):
        assert voltage_levels(1.1, 1.1, 0.05) == (1.1,)

    def test_dvfs_model_below_threshold_and_gated(self):
        m = DvfsModel()
        assert m.freq(m.v_th) == 0.0
        assert m.freq(V_GATED) == 0.0
        assert m.leak_power(V_GATED) == 0.0
        assert m.dyn_energy_scale(m.v_nom) == 1.0

    def test_compile_with_single_voltage_acc(self):
        acc = dataclasses.replace(ACC, v_min=ACC.v_nom, v_max=ACC.v_nom)
        assert acc.levels() == (ACC.v_nom,)
        specs = [conv_spec("c", 8, 8, 8, 16, 3), fc_spec("f", 256, 10)]
        costs = characterize_network(specs, acc)
        floor = sum(nominal_latency(c, acc) for c in costs)
        sched = pfdnn_compile(
            specs, MinEnergy(deadline_s=4 * floor), acc=acc)
        # every non-gated assignment sits on the only rail
        for lv in sched.layer_voltages:
            for v in lv:
                assert v in (ACC.v_nom, V_GATED)
        assert sched.t_infer <= 4 * floor

    def test_single_voltage_infeasible_when_too_tight(self):
        from repro.core.goals import InfeasibleGoal

        acc = dataclasses.replace(ACC, v_min=ACC.v_nom, v_max=ACC.v_nom)
        specs = [conv_spec("c", 8, 8, 8, 16, 3)]
        floor = sum(nominal_latency(c, ACC)
                    for c in characterize_network(specs, acc))
        result = pfdnn_compile(
            specs, MinEnergy(deadline_s=floor * 0.01), acc=acc)
        assert isinstance(result, InfeasibleGoal)
