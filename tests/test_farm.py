"""Compile farm + on-disk artifact tier: crash consistency, concurrent
writers, LRU eviction, schema versioning/migration, fair-share
admission, and farm-vs-solo bit identity.

The load-bearing property mirrors ``test_service.py``: no matter which
process compiled an artifact or which tier answered the lookup
(memory, per-entry disk file, migrated schema-1 snapshot, farm
worker), the emitted schedule is bit-identical to a solo compile —
pinned here against the 23 goldens.
"""

import json
import multiprocessing
import os
import pathlib
import signal
import time

import pytest

from conftest import max_rate
from repro.core import OrchestratorConfig, compile_power_schedule
from repro.models.edge_cnn import edge_network
from repro.service import (
    ArtifactStore,
    CompileFarm,
    CompileRequest,
    CompileService,
    DiskTier,
    FairShareAdmission,
    FarmResult,
    latency_summary,
)
from repro.service.disk import DISK_SCHEMA, entry_digest

GOLDEN = json.loads(
    (pathlib.Path(__file__).parent / "golden" / "pipeline.json")
    .read_text())


def _cfg_for(key: str):
    network, frac, n_rails, policy = key.split("|")
    rate = max_rate(network) * float(frac)
    return network, rate, OrchestratorConfig(policy=policy,
                                             n_max_rails=int(n_rails))


def _request_for(key: str) -> CompileRequest:
    network, rate, cfg = _cfg_for(key)
    return CompileRequest(edge_network(network), rate, cfg,
                          network=network)


def _assert_matches_golden(key: str, sched) -> None:
    """The schedule matches the pinned pipeline golden: exact rails and
    voltage path, energies to the goldens' float tolerance (the frozen
    file predates refactors that moved the last ulp — same convention
    as ``test_pipeline_equivalence``)."""
    g = GOLDEN[key]
    assert sched is not None, f"{key}: farm returned infeasible"
    assert sched.feasible == g["feasible"]
    assert sched.e_total == pytest.approx(g["e_total"], rel=1e-9)
    assert sched.t_infer == pytest.approx(g["t_infer"], rel=1e-9)
    assert list(sched.rails) == g["rails"]
    assert [list(v) for v in sched.layer_voltages] \
        == g["layer_voltages"]


def _assert_same_schedule(a, b) -> None:
    """Bit-identical deployment artifacts — the farm-vs-solo guarantee
    (stronger than the golden-file tolerance)."""
    assert a.rails == b.rails
    assert a.layer_voltages == b.layer_voltages
    assert a.awake_banks == b.awake_banks
    assert a.e_total == b.e_total
    assert a.t_infer == b.t_infer
    assert a.e_op == b.e_op
    assert a.e_trans == b.e_trans
    assert a.e_idle == b.e_idle
    assert a.feasible == b.feasible


# ------------------------------------------------- disk tier: digests

def test_entry_digest_length_prefixed():
    """Distinct part tuples never collide by concatenation, and bytes
    hash differently from their repr."""
    assert entry_digest("ab", "c") != entry_digest("a", "bc")
    assert entry_digest("abc") != entry_digest("ab", "c")
    assert entry_digest(b"x") != entry_digest("x")
    assert entry_digest("k", 1.0) == entry_digest("k", 1.0)


# ------------------------------------- crash consistency / concurrency

def _orphaning_writer(root: str, digest: str) -> None:
    """Simulated mid-publish crash victim: writes the temp file, then
    blocks forever — the parent SIGKILLs it before the os.replace."""
    tier_dir = pathlib.Path(root) / "schedules"
    tmp = tier_dir / f"{digest}.json.{os.getpid()}.0.tmp"
    tmp.write_bytes(b'{"schema": 2, "key": ["truncat')   # partial entry
    time.sleep(600)


def test_killed_writer_mid_publish(tmp_path):
    """A writer killed between temp-write and os.replace leaves an
    orphan ``*.tmp``: a fresh store opens cleanly, every lookup ignores
    the orphan, re-publication succeeds, and the orphan is swept once
    stale."""
    root = tmp_path / "store"
    tier = DiskTier(root)
    key = ("content", "min_energy|0.01", "cfg")
    digest = tier.schedule_digest(key)

    ctx = multiprocessing.get_context("fork")
    p = ctx.Process(target=_orphaning_writer, args=(str(root), digest))
    p.start()
    tmp_name = f"{digest}.json.{p.pid}.0.tmp"
    orphan = root / "schedules" / tmp_name
    for _ in range(200):                      # wait for the temp write
        if orphan.exists():
            break
        time.sleep(0.05)
    assert orphan.exists()
    os.kill(p.pid, signal.SIGKILL)            # die before os.replace
    p.join(timeout=10)

    # fresh open: clean, orphan ignored by lookups and stats
    tier2 = DiskTier(root)
    assert tier2.get_schedule(key) is None
    assert tier2.stats()["entries"]["schedules"] == 0
    assert orphan.exists()                    # fresh orphan: not swept

    # re-publication over the orphan works and reads back
    tier2.put_schedule(key, "payload")
    assert tier2.get_schedule(key) == "payload"

    # once stale, the next open sweeps it
    old = time.time() - 7200
    os.utime(orphan, (old, old))
    tier3 = DiskTier(root)
    assert not orphan.exists()
    assert tier3.orphans_swept == 1
    assert tier3.get_schedule(key) == "payload"


def _racing_writer(root: str, payload: str, n: int) -> None:
    tier = DiskTier(root)
    key = ("content", "goal", "cfg")
    for _ in range(n):
        tier.put_schedule(key, payload)


def test_two_process_same_digest_race(tmp_path):
    """Two processes hammering the same digest: entries are
    content-addressed, so the racing payloads are byte-identical and
    last-writer-wins publication can never tear or corrupt — exactly
    one final file, no leftover temps, payload intact."""
    root = tmp_path / "store"
    DiskTier(root)                            # create layout up front
    payload = json.dumps({"rails": [0.9, 1.3], "e": 1.25e-4})
    ctx = multiprocessing.get_context("fork")
    procs = [ctx.Process(target=_racing_writer,
                         args=(str(root), payload, 60))
             for _ in range(2)]
    for p in procs:
        p.start()
    for p in procs:
        p.join(timeout=120)
        assert p.exitcode == 0

    entries = [p for p in (root / "schedules").iterdir()
               if not p.name.endswith(".tmp")]
    tmps = [p for p in (root / "schedules").iterdir()
            if p.name.endswith(".tmp")]
    assert len(entries) == 1 and not tmps
    ent = json.loads(entries[0].read_bytes().decode())
    assert ent["payload"] == payload
    assert DiskTier(root).get_schedule(("content", "goal", "cfg")) \
        == payload


# ------------------------------------------------- eviction + schema

def test_lru_eviction_oldest_first(tmp_path):
    tier = DiskTier(tmp_path / "store", max_entries=2)
    keys = [("c", f"goal{i}", "cfg") for i in range(4)]
    for i, key in enumerate(keys):
        tier.put_schedule(key, f"payload{i}")
        # deterministic mtime order regardless of fs timestamp
        # granularity
        path = tier._path("schedules", tier.schedule_digest(key),
                          ".json")
        os.utime(path, (1000.0 + i, 1000.0 + i))
    # a read bumps recency: key 0 becomes the newest
    now = time.time()
    assert tier.get_schedule(keys[0]) == "payload0"
    path0 = tier._path("schedules", tier.schedule_digest(keys[0]),
                       ".json")
    assert path0.stat().st_mtime >= now - 5

    assert tier.evict_to_budget() == 2
    assert tier.get_schedule(keys[0]) == "payload0"   # recently read
    assert tier.get_schedule(keys[3]) == "payload3"   # newest write
    assert tier.get_schedule(keys[1]) is None          # oldest: evicted
    assert tier.get_schedule(keys[2]) is None
    assert tier.stats()["evictions"]["schedules"] == 2
    assert tier.stats()["entries"]["schedules"] == 2


def test_unknown_newer_schema_refuses(tmp_path):
    root = tmp_path / "store"
    DiskTier(root)
    (root / "STORE_META.json").write_text(json.dumps({"schema": 99}))
    with pytest.raises(ValueError, match="schema 99"):
        DiskTier(root)
    with pytest.raises(ValueError, match="schema 99"):
        ArtifactStore(disk_path=root)


def test_meta_pins_current_schema(tmp_path):
    root = tmp_path / "store"
    DiskTier(root)
    meta = json.loads((root / "STORE_META.json").read_text())
    assert meta["schema"] == DISK_SCHEMA == 2
    assert DiskTier(root).schema == DISK_SCHEMA   # reopen accepts


# ------------------------------------------------- store: disk tier

@pytest.fixture(scope="module")
def shared_dir(tmp_path_factory):
    """A disk store populated by one cold inline farm run over every
    golden config, submitted by three tenants — the shared-warm state
    the cross-process tests start from."""
    root = tmp_path_factory.mktemp("farm") / "store"
    farm = CompileFarm(root, n_workers=0, batch_size=8)
    tenants = ("teamA", "teamB", "teamC")
    uid_to_key = {}
    for i, key in enumerate(sorted(GOLDEN)):
        (uid,) = farm.submit(tenants[i % 3], [_request_for(key)])
        uid_to_key[uid] = key
    results = farm.drain()
    farm.close()
    return root, {uid_to_key[uid]: res for uid, res in results.items()}


@pytest.mark.parametrize("key", sorted(GOLDEN))
def test_farm_results_match_goldens(key, shared_dir):
    """Every schedule the farm emitted is bit-identical to the solo
    pipeline golden, and carries its provenance."""
    _, results = shared_dir
    res = results[key]
    assert res.error is None
    assert isinstance(res, FarmResult) and res.latency_s >= 0
    _assert_matches_golden(key, res.value)


@pytest.mark.parametrize("key", sorted(GOLDEN)[::5])
def test_farm_vs_solo_bit_identical(key, shared_dir):
    """The farm's schedule is bit-identical to a solo
    ``compile_power_schedule`` of the same point — every field, not
    just to golden tolerance."""
    _, results = shared_dir
    network, rate, cfg = _cfg_for(key)
    solo = compile_power_schedule(edge_network(network), rate, cfg=cfg,
                                  network=network)
    _assert_same_schedule(solo, results[key].value)


@pytest.mark.parametrize("key", sorted(GOLDEN)[::5])
def test_disk_warm_store_matches_goldens(key, shared_dir):
    """A *fresh* store over the farm's directory (a new process, as far
    as the tier can tell) serves the same configs shared-warm: the
    schedule streams in as a disk hit and stays bit-identical."""
    root, _ = shared_dir
    svc = CompileService(store=ArtifactStore(disk_path=root))
    network, rate, cfg = _cfg_for(key)
    sched = svc.compile(edge_network(network), rate, cfg=cfg,
                        network=network)
    _assert_matches_golden(key, sched)
    stats = svc.store.stats()
    assert stats["disk_hits"]["schedule"] == 1
    assert stats["hits"]["schedule"] == 1


def test_disk_warm_solve_parity(shared_dir):
    """With the schedule cache disabled, a fresh store still warm-starts
    the full solve from the disk tier's tables (master/transition/
    pruning disk hits) and reproduces the golden exactly."""
    root, _ = shared_dir
    key = "squeezenet1.1|0.9|2|pfdnn"   # a full-DP policy: uses tables
    svc = CompileService(store=ArtifactStore(disk_path=root),
                         use_schedule_cache=False)
    network, rate, cfg = _cfg_for(key)
    sched = svc.compile(edge_network(network), rate, cfg=cfg,
                        network=network)
    _assert_matches_golden(key, sched)
    dh = svc.store.stats()["disk_hits"]
    assert dh["master"] >= 1
    assert dh["transition"] >= 1


def test_store_clear_streams_back_from_disk(tmp_path):
    key = sorted(GOLDEN)[0]
    network, rate, cfg = _cfg_for(key)
    svc = CompileService(disk_path=tmp_path / "store")
    first = svc.compile(edge_network(network), rate, cfg=cfg,
                        network=network)
    svc.store.clear()                 # memory gone, disk untouched
    again = svc.compile(edge_network(network), rate, cfg=cfg,
                        network=network)
    _assert_matches_golden(key, first)
    _assert_matches_golden(key, again)
    assert svc.store.stats()["disk_hits"]["schedule"] == 1


def test_deferred_publication_batches_and_dedups(tmp_path):
    store = ArtifactStore(disk_path=tmp_path / "store")
    sched_dir = tmp_path / "store" / "schedules"
    with store.deferred_publication():
        store.put_schedule(("c", "g1", "cfg"), None)
        store.put_schedule(("c", "g1", "cfg"), None)   # dedup
        store.put_schedule(("c", "g2", "cfg"), None)
        with store.deferred_publication():             # nested: no-op
            store.put_schedule(("c", "g3", "cfg"), None)
        assert list(sched_dir.iterdir()) == []         # still buffered
    files = [p for p in sched_dir.iterdir()
             if not p.name.endswith(".tmp")]
    assert len(files) == 3
    # memory answered throughout; nothing re-published on read
    assert store.schedule(("c", "g3", "cfg")) is not None


def test_store_eviction_budget(tmp_path):
    store = ArtifactStore(disk_path=tmp_path / "store",
                          max_disk_entries=1)
    for i in range(3):
        store.put_schedule(("c", f"g{i}", "cfg"), None)
    store.flush_disk()
    stats = store.stats()["disk"]
    assert stats["entries"]["schedules"] == 1
    assert sum(stats["evictions"].values()) == 2


# ------------------------------------- schema-1 snapshot migration

def test_snapshot_migration_roundtrip(tmp_path):
    """A pre-PR monolithic ``save()`` snapshot (schema 1) loads into a
    disk-backed store, republishes as per-entry schema-2 files, and a
    *fresh* store over that directory serves the entries shared-warm,
    bit-identical to the golden."""
    key = "squeezenet1.1|0.9|2|pfdnn"   # full-DP: snapshot gets tables
    network, rate, cfg = _cfg_for(key)
    # a memory-only service, exactly what a pre-PR deployment ran
    svc = CompileService()
    svc.compile(edge_network(network), rate, cfg=cfg, network=network)
    snap = tmp_path / "snapshot.npz"
    svc.store.save(snap)

    root = tmp_path / "store"
    migrated = ArtifactStore(disk_path=root).load(snap)
    tier_stats = migrated.stats()["disk"]
    assert tier_stats["entries"]["schedules"] >= 1
    assert tier_stats["entries"]["masters"] >= 1
    assert tier_stats["entries"]["transitions"] >= 1

    fresh = CompileService(store=ArtifactStore(disk_path=root))
    sched = fresh.compile(edge_network(network), rate, cfg=cfg,
                          network=network)
    _assert_matches_golden(key, sched)
    assert fresh.store.stats()["disk_hits"]["schedule"] == 1


def test_unknown_snapshot_version_refuses(tmp_path):
    import numpy as np

    snap = tmp_path / "bad.npz"
    manifest = np.frombuffer(json.dumps({"version": 9}).encode(),
                             dtype=np.uint8)
    np.savez_compressed(snap, manifest=manifest)
    with pytest.raises(ValueError, match="version 9"):
        ArtifactStore().load(snap)


# ------------------------------------------------- fair-share admission

def test_fair_share_round_robin_interleave():
    adm = FairShareAdmission()
    for i in range(6):
        adm.push("A", f"A{i}")
    for i in range(2):
        adm.push("B", f"B{i}")
    for i in range(2):
        adm.push("C", f"C{i}")
    batch = adm.next_batch(6)
    # one per tenant per turn, FIFO within tenant
    assert batch == ["A0", "B0", "C0", "A1", "B1", "C1"]
    assert adm.next_batch(10) == ["A2", "A3", "A4", "A5"]
    assert adm.pending() == 0


def test_fair_share_late_tenant_admitted_next_batch():
    """A late-arriving tenant is not starved behind an earlier burst:
    it gets its fair share of the very next batch."""
    adm = FairShareAdmission()
    for i in range(100):
        adm.push("burst", f"b{i}")
    assert adm.next_batch(4) == ["b0", "b1", "b2", "b3"]
    adm.push("interactive", "i0")
    nxt = adm.next_batch(4)
    assert "i0" in nxt
    assert nxt.count("i0") == 1 and len(nxt) == 4


def test_latency_summary_per_tenant():
    def res(tenant, lat):
        return FarmResult(uid=0, tenant=tenant, value=None,
                          latency_s=lat, worker=0, batch_id=0,
                          batch_wall_s=lat)

    rows = [res("A", s) for s in (0.1, 0.2, 0.3)] \
        + [res("B", s) for s in (1.0, 2.0)]
    summary = latency_summary(rows)
    assert summary["fleet"]["n"] == 5
    assert summary["fleet"]["max_s"] == 2.0
    assert summary["tenants"]["A"]["p50_s"] == pytest.approx(0.2)
    assert summary["tenants"]["B"]["n"] == 2


# ------------------------------------------------- farm end-to-end

def test_farm_inline_repeat_traffic_hits_cache(tmp_path):
    """Repeat requests across tenants answer from the shared schedule
    cache (hits counted), and every copy is bit-identical."""
    key = "squeezenet1.1|0.9|2|pfdnn"
    farm = CompileFarm(tmp_path / "store", n_workers=0, batch_size=4)
    uids_a = farm.submit("A", [_request_for(key)] * 3)
    uids_b = farm.submit("B", [_request_for(key)] * 3)
    results = farm.drain()
    farm.close()
    for uid in uids_a + uids_b:
        _assert_matches_golden(key, results[uid].value)
    counters = farm.counters()
    # batch 1 solves once (in-batch duplicates dedup to the same solve);
    # batch 2 answers entirely from the schedule cache
    assert counters["hits"]["schedule"] >= 2
    assert counters["misses"]["schedule"] >= 1
    assert farm.n_batches >= 2


def test_farm_validates_arguments(tmp_path):
    with pytest.raises(ValueError, match="n_workers"):
        CompileFarm(tmp_path / "s", n_workers=-1)
    with pytest.raises(ValueError, match="batch_size"):
        CompileFarm(tmp_path / "s", batch_size=0)
    (tmp_path / "bad").mkdir()
    (tmp_path / "bad" / "STORE_META.json").write_text('{"schema": 99}')
    with pytest.raises(ValueError, match="schema 99"):
        CompileFarm(tmp_path / "bad")   # fails at construction


def test_farm_cross_process_shared_warm(tmp_path):
    """The real thing: a 2-worker spawn farm compiles cold; a second
    farm with *fresh worker processes* over the same directory answers
    shared-warm from cross-process disk hits — bit-identical to the
    goldens both times."""
    keys = ["squeezenet1.1|0.9|2|pfdnn",
            "mobilenetv3-small|0.85|2|pfdnn"]
    root = tmp_path / "store"

    def run_farm():
        with CompileFarm(root, n_workers=2, batch_size=2) as farm:
            uid_to_key = {}
            for tenant, key in zip(("A", "B", "A", "B"), keys * 2):
                (uid,) = farm.submit(tenant, [_request_for(key)])
                uid_to_key[uid] = key
            results = farm.drain()
            counters = farm.counters()
        return {uid_to_key[u]: r for u, r in results.items()}, counters

    cold, _ = run_farm()
    warm, warm_counters = run_farm()           # fresh processes
    for key in keys:
        _assert_matches_golden(key, cold[key].value)
        _assert_matches_golden(key, warm[key].value)
    for res in list(cold.values()) + list(warm.values()):
        assert res.error is None
    # cross-process sharing: the second farm never saw these compiles,
    # yet its workers answered from the first farm's published entries
    assert warm_counters["disk_hits"]["schedule"] >= 1


# ------------------------------------------------- service lifecycle

def test_service_close_and_context_manager(tmp_path):
    key = sorted(GOLDEN)[0]
    network, rate, cfg = _cfg_for(key)
    with CompileService(disk_path=tmp_path / "store") as svc:
        sched = svc.compile(edge_network(network), rate, cfg=cfg,
                            network=network)
        _assert_matches_golden(key, sched)
    svc.close()                        # idempotent
    # the service stays usable after close (sync path needs no pool)
    again = svc.compile(edge_network(network), rate, cfg=cfg,
                        network=network)
    _assert_matches_golden(key, again)


def test_service_rejects_store_and_disk_path(tmp_path):
    with pytest.raises(ValueError, match="not both"):
        CompileService(store=ArtifactStore(),
                       disk_path=tmp_path / "store")


def test_compile_accepts_store_path(tmp_path):
    """``compile_power_schedule(store=<path>)`` builds the disk-backed
    store inline — the one-liner migration for scripts that never
    touch the service API."""
    key = sorted(GOLDEN)[0]
    network, rate, cfg = _cfg_for(key)
    root = tmp_path / "store"
    first = compile_power_schedule(edge_network(network), rate, cfg=cfg,
                                   network=network, store=str(root))
    _assert_matches_golden(key, first)
    assert (root / "STORE_META.json").exists()
    again = compile_power_schedule(edge_network(network), rate, cfg=cfg,
                                   network=network, store=root)
    _assert_matches_golden(key, again)
    with pytest.raises(TypeError, match="store="):
        compile_power_schedule(edge_network(network), rate, cfg=cfg,
                               network=network, store=42)
