"""Goal-driven compile API (ISSUE 5): objectives/constraints as
first-class values.

The contracts under test:
  - the ``MinEnergy`` goal path is bit-identical to the legacy
    ``compile_power_schedule`` entry and reproduces every golden
    (numpy default; the tier1-jax CI job replays the whole file under
    ``PFDNN_BACKEND=jax``, and one case runs jax explicitly here);
  - ``MinLatency`` (the dual) never exceeds its energy budget, matches
    an exhaustive brute-force scan on tiny problems (the candidate
    pool covers the whole path space when k ≥ |paths|), and agrees
    with the dual ILP oracle where tractable;
  - weak duality (hypothesis property): tightening the budget never
    speeds up the schedule;
  - a ``ParetoFront`` compile through the fleet engine emits the same
    per-point schedules as independent MinEnergy compiles;
  - mixed-goal ``compile_many`` batches equal solo compiles;
  - infeasible goals come back as structured ``InfeasibleGoal`` values
    (reason + bound), cached by the service like the legacy sentinel,
    while the legacy wrapper keeps returning ``None``.
"""

import dataclasses
import itertools
import json
import pathlib

import numpy as np
import pytest

from conftest import max_rate, random_problem
from repro.core import (
    CompilationContext,
    InfeasibleGoal,
    MinEnergy,
    MinLatency,
    OrchestratorConfig,
    ParetoFront,
    ParetoFrontier,
    PowerSchedule,
    as_goal,
    available_backends,
    compile as compile_goal,
    compile_power_schedule,
    prune_problem,
    solve_budget_dp,
    solve_ilp_min_latency,
)
from repro.core.goals import (
    REASON_BUDGET,
    REASON_DEADLINE,
    REASON_POLICY,
)
from repro.core.problem import ScheduleProblem
from repro.models.edge_cnn import edge_network
from repro.service import ArtifactStore, CompileRequest, CompileService

GOLDEN = json.loads(
    (pathlib.Path(__file__).parent / "golden" / "pipeline.json")
    .read_text())

BACKENDS = list(available_backends())


# ----------------------------------------------------- goal value rules

def test_goal_validation():
    with pytest.raises(ValueError, match="exactly one"):
        MinEnergy()
    with pytest.raises(ValueError, match="exactly one"):
        MinEnergy(deadline_s=0.1, rate_hz=10.0)
    with pytest.raises(ValueError, match="positive"):
        MinEnergy(rate_hz=0.0)
    assert MinEnergy(rate_hz=40.0).deadline == 1.0 / 40.0
    assert MinEnergy(deadline_s=0.025).deadline == 0.025
    with pytest.raises(ValueError, match="positive"):
        MinLatency(energy_budget_j=-1.0)
    with pytest.raises(ValueError, match="exactly one"):
        ParetoFront()
    with pytest.raises(ValueError, match="at least 2"):
        ParetoFront(n_points=1)
    with pytest.raises(ValueError, match="positive"):
        ParetoFront(deadlines=(0.1, -0.2))
    assert ParetoFront(deadlines=(0.3, 0.1)).deadlines == (0.1, 0.3)
    with pytest.raises(TypeError, match="goal must be"):
        as_goal("min_energy")
    with pytest.raises(TypeError, match="goal must be"):
        compile_goal(edge_network("squeezenet1.1"), 40.0)


# ------------------------------------------- MinEnergy == golden path

@pytest.mark.parametrize("key", sorted(GOLDEN))
def test_min_energy_goal_matches_golden(key):
    """Every golden case reproduced through the new goal entry — the
    default path is unchanged by the API redesign."""
    network, frac, n_rails, policy = key.split("|")
    golden = GOLDEN[key]
    rate = max_rate(network) * float(frac)
    result = compile_goal(
        edge_network(network), MinEnergy(rate_hz=rate),
        cfg=OrchestratorConfig(policy=policy, n_max_rails=int(n_rails)),
        network=network)
    if not golden["feasible"]:
        assert isinstance(result, InfeasibleGoal)
        assert result.reason == REASON_DEADLINE
        return
    assert isinstance(result, PowerSchedule)
    assert result.e_total == pytest.approx(golden["e_total"], rel=1e-9)
    assert result.t_infer == pytest.approx(golden["t_infer"], rel=1e-9)
    assert list(result.rails) == golden["rails"]
    assert [list(v) for v in result.layer_voltages] == \
        golden["layer_voltages"]
    # the artifact records its goal + binding constraint
    assert result.goal == {"type": "min_energy",
                           "deadline_s": 1.0 / rate}
    assert result.binding_constraint == "deadline"


def test_wrapper_is_bit_identical_to_goal_path():
    rate = max_rate("squeezenet1.1") * 0.9
    specs = edge_network("squeezenet1.1")
    cfg = OrchestratorConfig(policy="pfdnn", n_max_rails=2)
    legacy = compile_power_schedule(specs, rate, cfg=cfg, network="sqz")
    goal = compile_goal(specs, MinEnergy(rate_hz=rate), cfg=cfg,
                        network="sqz")
    assert legacy.e_total == goal.e_total
    assert legacy.t_infer == goal.t_infer
    assert legacy.layer_voltages == goal.layer_voltages
    assert legacy.rails == goal.rails


@pytest.mark.skipif("jax" not in BACKENDS, reason="jax not installed")
def test_min_energy_goal_matches_golden_jax():
    key = "squeezenet1.1|0.9|2|pfdnn"
    network, frac, n_rails, policy = key.split("|")
    golden = GOLDEN[key]
    rate = max_rate(network) * float(frac)
    result = compile_goal(
        edge_network(network), MinEnergy(rate_hz=rate),
        cfg=OrchestratorConfig(policy=policy, n_max_rails=int(n_rails),
                               backend="jax"),
        network=network)
    assert result.e_total == pytest.approx(golden["e_total"], rel=1e-9)
    assert [list(v) for v in result.layer_voltages] == \
        golden["layer_voltages"]


# ------------------------------------------------- ctx reuse decoupling

def test_one_context_serves_all_goals_and_deadlines():
    """The context is decoupled from a single deadline: one ctx serves
    MinEnergy at any rate, MinLatency, and ParetoFront."""
    specs = edge_network("squeezenet1.1")
    rate = max_rate("squeezenet1.1") * 0.9
    cfg = OrchestratorConfig(policy="pfdnn", n_max_rails=2)
    ctx = CompilationContext(specs, network="sqz")      # deadline-free
    a = compile_goal(specs, MinEnergy(rate_hz=rate), cfg=cfg, ctx=ctx)
    b = compile_goal(specs, MinEnergy(rate_hz=rate * 0.6), cfg=cfg,
                     ctx=ctx)
    assert a.t_max != b.t_max and a.e_total != b.e_total
    for sched, rr in ((a, rate), (b, rate * 0.6)):
        solo = compile_goal(specs, MinEnergy(rate_hz=rr), cfg=cfg,
                            network="sqz")
        assert sched.e_total == solo.e_total
        assert sched.layer_voltages == solo.layer_voltages
    d = compile_goal(
        specs, MinLatency(energy_budget_j=(a.e_op + a.e_trans) * 1.2),
        cfg=cfg, ctx=ctx)
    assert isinstance(d, PowerSchedule)
    # a deadline-free ctx through a legacy-signature policy must raise,
    # not silently compile for an undefined deadline
    from repro.core import get_policy, register_policy

    name = "test_goalless_policy"
    try:
        @register_policy(name)
        def legacy_policy(ctx, cfg):            # pragma: no cover
            return None

        with pytest.raises(ValueError, match="does not accept goal"):
            compile_goal(specs, MinLatency(energy_budget_j=1.0),
                         cfg=OrchestratorConfig(policy=name), ctx=ctx)
    finally:
        from repro.core import policies as _p

        _p._REGISTRY.pop(name, None)


# --------------------------------------------------- MinLatency (dual)

def _dual_problem(prob: ScheduleProblem) -> ScheduleProblem:
    """Deadline-free copy of a conftest problem (t_max=0: no idle)."""
    return ScheduleProblem(
        layer_states=prob.layer_states, t_max=0.0, idle=prob.idle,
        transition_model=prob.transition_model)


def _brute_force_dual(prob: ScheduleProblem, budget: float):
    """Exhaustive fastest-within-budget scan: (t, e_infer) or None."""
    best = None
    for path in itertools.product(*(range(len(s))
                                    for s in prob.layer_states)):
        r = prob.evaluate(list(path))
        e = r["e_op"] + r["e_trans"]
        if e > budget:
            continue
        key = (r["t_infer"], e)
        if best is None or key < best:
            best = key
    return best


@pytest.mark.parametrize("seed", range(6))
def test_budget_dp_matches_brute_force(seed):
    """With k ≥ |paths| the dual pool covers the whole path space, so
    solve_budget_dp is exact on tiny problems."""
    rng = np.random.default_rng(seed)
    prob = _dual_problem(random_problem(rng, n_layers=3, n_states=3))
    energies = sorted(
        prob.evaluate(list(p))["e_op"] + prob.evaluate(list(p))["e_trans"]
        for p in itertools.product(*(range(3) for _ in range(3))))
    for budget in (energies[0] * 0.99, energies[0] * 1.0001,
                   energies[len(energies) // 2], energies[-1] * 1.1):
        best, cands, stats = solve_budget_dp(prob, budget,
                                             k_candidates=32)
        ref = _brute_force_dual(prob, budget)
        if ref is None:
            assert best is None
            continue
        assert best is not None
        assert best["e_op"] + best["e_trans"] <= budget
        assert best["t_infer"] == pytest.approx(ref[0], rel=1e-12)
        for c in cands:
            assert c["e_op"] + c["e_trans"] <= budget


@pytest.mark.parametrize("seed", range(3))
def test_dual_ilp_oracle_matches_brute_force(seed):
    pytest.importorskip("scipy")
    rng = np.random.default_rng(seed)
    prob = _dual_problem(random_problem(rng, n_layers=3, n_states=3))
    energies = [prob.evaluate(list(p))["e_op"] +
                prob.evaluate(list(p))["e_trans"]
                for p in itertools.product(*(range(3)
                                             for _ in range(3)))]
    budget = float(np.median(energies))
    ref = _brute_force_dual(prob, budget)
    out = solve_ilp_min_latency(prob, budget)
    assert out["feasible"]
    assert out["e_op"] + out["e_trans"] <= budget * (1 + 1e-9)
    assert out["t_infer"] == pytest.approx(ref[0], rel=1e-9)


def test_min_latency_compile_respects_budget_and_matches_ilp():
    """End-to-end dual compile on a real network: budget respected,
    dual artifact semantics, and the dual ILP oracle can't beat the
    sweep's pick on its own rails by more than tolerance."""
    pytest.importorskip("scipy")
    specs = edge_network("squeezenet1.1")
    rate = max_rate("squeezenet1.1") * 0.5
    cfg = OrchestratorConfig(policy="pfdnn", n_max_rails=2)
    ref = compile_goal(specs, MinEnergy(rate_hz=rate), cfg=cfg,
                       network="sqz")
    budget = (ref.e_op + ref.e_trans) * 1.25
    sched = compile_goal(specs, MinLatency(energy_budget_j=budget),
                         cfg=cfg, network="sqz")
    assert isinstance(sched, PowerSchedule)
    assert sched.e_op + sched.e_trans <= budget
    assert sched.e_idle == 0.0
    assert sched.feasible
    assert sched.t_max == sched.t_infer          # zero slack by design
    assert sched.binding_constraint == "energy_budget"
    assert sched.goal == {"type": "min_latency",
                          "energy_budget_j": budget}
    ilp = compile_goal(specs, MinLatency(energy_budget_j=budget),
                       cfg=dataclasses.replace(cfg, policy="ilp"),
                       network="sqz")
    assert isinstance(ilp, PowerSchedule)
    assert ilp.e_op + ilp.e_trans <= budget * (1 + 1e-9)
    # the oracle runs on the rails the dual sweep selected, so it can
    # only match or beat the heuristic there
    assert ilp.t_infer <= sched.t_infer * (1 + 1e-9)


def test_min_latency_selects_across_rail_subsets():
    """A looser budget buys a faster schedule (possibly on different
    rails); every result stays within its own budget."""
    specs = edge_network("squeezenet1.1")
    rate = max_rate("squeezenet1.1") * 0.5
    cfg = OrchestratorConfig(policy="pfdnn", n_max_rails=2)
    ref = compile_goal(specs, MinEnergy(rate_hz=rate), cfg=cfg,
                       network="sqz")
    base = ref.e_op + ref.e_trans
    times = []
    for mult in (1.01, 1.4, 2.5):
        s = compile_goal(specs,
                         MinLatency(energy_budget_j=base * mult),
                         cfg=cfg, network="sqz")
        assert s.e_op + s.e_trans <= base * mult
        times.append(s.t_infer)
    assert times[0] >= times[1] >= times[2]
    assert times[2] < times[0]          # the budget axis really moves T


# ------------------------------------------------ weak duality property

try:
    import hypothesis
    from hypothesis import given, settings, strategies as st

    @given(seed=st.integers(0, 10_000),
           f1=st.floats(0.05, 0.95), f2=st.floats(0.05, 0.95))
    @settings(max_examples=25, deadline=None)
    def test_weak_duality_tighter_budget_never_faster(seed, f1, f2):
        """Budgets are quantiles of the (exactly enumerated) path
        energy range; k ≥ |paths| makes the solves exact, so the
        monotonicity must hold exactly: b_lo ≤ b_hi ⇒ t(b_lo) ≥
        t(b_hi), and every result respects its own budget."""
        rng = np.random.default_rng(seed)
        prob = _dual_problem(random_problem(rng, n_layers=3,
                                            n_states=3))
        evals = [prob.evaluate(list(p))
                 for p in itertools.product(*(range(3)
                                              for _ in range(3)))]
        energies = sorted(r["e_op"] + r["e_trans"] for r in evals)
        span = energies[-1] - energies[0]
        b_lo, b_hi = sorted((energies[0] + f1 * span,
                             energies[0] + f2 * span))
        r_lo, _, _ = solve_budget_dp(prob, b_lo, k_candidates=32)
        r_hi, _, _ = solve_budget_dp(prob, b_hi, k_candidates=32)
        assert r_hi is not None        # b_hi ≥ min energy by design
        assert r_hi["e_op"] + r_hi["e_trans"] <= b_hi
        if r_lo is not None:
            assert r_lo["e_op"] + r_lo["e_trans"] <= b_lo
            assert r_lo["t_infer"] >= r_hi["t_infer"] - 1e-18
except ImportError:                               # pragma: no cover
    pass


# ----------------------------------------------------- Pareto frontier

def test_pareto_front_equals_solo_min_energy_compiles():
    specs = edge_network("squeezenet1.1")
    cfg = OrchestratorConfig(policy="pfdnn", n_max_rails=2)
    frontier = compile_goal(specs, ParetoFront(n_points=4), cfg=cfg,
                            network="sqz")
    assert isinstance(frontier, ParetoFrontier)
    assert len(frontier.points) == 4
    deadlines = [p.deadline_s for p in frontier.points]
    assert deadlines == sorted(deadlines)
    for p in frontier.points:
        solo = compile_goal(specs, MinEnergy(deadline_s=p.deadline_s),
                            cfg=cfg, network="sqz")
        if p.feasible:
            assert p.schedule.e_total == solo.e_total
            assert p.schedule.t_infer == solo.t_infer
            assert p.schedule.layer_voltages == solo.layer_voltages
            assert p.schedule.rails == solo.rails
        else:
            assert isinstance(solo, InfeasibleGoal)
    # energy is non-increasing as deadlines relax (schedules with more
    # slack can only save energy), over the feasible prefix
    feas = frontier.feasible_points()
    e_infer = [p.schedule.e_op + p.schedule.e_trans for p in feas]
    assert all(a >= b - 1e-18 for a, b in zip(e_infer, e_infer[1:]))


def test_pareto_front_explicit_deadlines_and_infeasible_points():
    specs = edge_network("squeezenet1.1")
    cfg = OrchestratorConfig(policy="pfdnn", n_max_rails=2)
    t_ok = 1.0 / (max_rate("squeezenet1.1") * 0.8)
    frontier = compile_goal(
        specs, ParetoFront(deadlines=(1e-6, t_ok)), cfg=cfg,
        network="sqz")
    assert not frontier.points[0].feasible
    assert frontier.points[0].schedule.reason == REASON_DEADLINE
    assert frontier.points[1].feasible
    assert "infeasible" in frontier.summary()


def test_pareto_front_non_stackable_policy_falls_back_per_point():
    """Non-sweep policies still get a frontier (solo per-point path)."""
    specs = edge_network("squeezenet1.1")
    cfg = OrchestratorConfig(policy="greedy_gating")
    frontier = compile_goal(specs, ParetoFront(n_points=3), cfg=cfg,
                            network="sqz")
    assert isinstance(frontier, ParetoFrontier)
    for p in frontier.feasible_points():
        solo = compile_goal(specs, MinEnergy(deadline_s=p.deadline_s),
                            cfg=cfg, network="sqz")
        assert p.schedule.e_total == solo.e_total


# ------------------------------------------------- structured infeasible

def test_infeasible_goal_reasons_and_json_roundtrip():
    specs = edge_network("squeezenet1.1")
    cfg = OrchestratorConfig(policy="pfdnn", n_max_rails=2)
    inf_t = compile_goal(specs, MinEnergy(deadline_s=1e-7), cfg=cfg,
                         network="sqz")
    assert isinstance(inf_t, InfeasibleGoal)
    assert inf_t.reason == REASON_DEADLINE
    assert inf_t.detail["deadline_s"] == 1e-7
    assert inf_t.detail["min_time_lower_bound_s"] > 1e-7
    inf_e = compile_goal(specs, MinLatency(energy_budget_j=1e-12),
                         cfg=cfg, network="sqz")
    assert isinstance(inf_e, InfeasibleGoal)
    assert inf_e.reason == REASON_BUDGET
    assert inf_e.detail["min_energy_lower_bound_j"] > 1e-12
    back = InfeasibleGoal.from_json(inf_e.to_json())
    assert back == inf_e
    # legacy wrapper: still None
    assert compile_power_schedule(specs, 1e7, cfg=cfg) is None


def test_infeasible_reason_is_honest_about_policy_failures():
    """A policy returning no schedule on a goal that is NOT provably
    impossible must not claim the constraint lies below the bound —
    callers would renegotiate a constraint that was never the
    problem."""
    from repro.core.orchestrator import infeasible_result

    specs = edge_network("squeezenet1.1")
    ctx = CompilationContext(specs, network="sqz")
    t_bound = ctx.min_t_op_bound(ctx.levels)
    e_bound = ctx.min_e_op_bound(ctx.levels)
    assert infeasible_result(MinEnergy(deadline_s=t_bound * 0.5),
                             ctx).reason == REASON_DEADLINE
    assert infeasible_result(MinEnergy(deadline_s=t_bound * 2.0),
                             ctx).reason == REASON_POLICY
    assert infeasible_result(MinLatency(energy_budget_j=e_bound * 0.5),
                             ctx).reason == REASON_BUDGET
    assert infeasible_result(MinLatency(energy_budget_j=e_bound * 2.0),
                             ctx).reason == REASON_POLICY


def test_infeasible_goal_cached_by_service():
    specs = edge_network("squeezenet1.1")
    cfg = OrchestratorConfig(policy="pfdnn", n_max_rails=2)
    svc = CompileService()
    goal = MinLatency(energy_budget_j=1e-12)
    first = svc.compile(specs, cfg=cfg, network="sqz", goal=goal)
    assert isinstance(first, InfeasibleGoal)
    hits_before = svc.store.stats()["hits"]["schedule"]
    again = svc.compile(specs, cfg=cfg, network="other", goal=goal)
    assert svc.store.stats()["hits"]["schedule"] == hits_before + 1
    assert isinstance(again, InfeasibleGoal)
    assert again.reason == first.reason
    assert again.network == "other"     # label rebinds, content cached
    # legacy rate form still yields None on infeasible, also cached
    assert svc.compile(specs, 1e9, cfg=cfg, network="sqz") is None
    assert svc.compile(specs, 1e9, cfg=cfg, network="sqz") is None


def test_pre_goal_snapshot_schedule_keys_migrate_on_load(tmp_path):
    """A disk snapshot written before the goal API keyed schedules by
    repr(rate); load() normalizes those keys to the MinEnergy goal
    form so old warm stores keep answering (same float division, so
    the migrated key is exact)."""
    specs = edge_network("squeezenet1.1")
    rate = max_rate("squeezenet1.1") * 0.9
    cfg = OrchestratorConfig(policy="pfdnn", n_max_rails=2)
    svc = CompileService()
    sched = svc.compile(specs, rate, cfg=cfg, network="sqz")
    # rewrite the cache under the pre-goal key format and snapshot it
    (key, text), = svc.store._schedules.items()
    old_key = (key[0], repr(float(rate)), key[2])
    svc.store._schedules.clear()
    svc.store._schedules[old_key] = text
    path = tmp_path / "store.npz"
    svc.save(path)
    fresh = CompileService().load(path)
    hits = fresh.store.stats()["hits"]["schedule"]
    warm = fresh.compile(specs, rate, cfg=cfg, network="sqz")
    assert fresh.store.stats()["hits"]["schedule"] == hits + 1
    assert warm.e_total == sched.e_total
    assert warm.layer_voltages == sched.layer_voltages


def test_conflicting_rate_and_goal_rejected():
    specs = edge_network("squeezenet1.1")
    svc = CompileService()
    with pytest.raises(ValueError, match="both target_rate_hz and"):
        svc.compile(specs, 40.0, goal=MinEnergy(rate_hz=30.0))
    with pytest.raises(ValueError, match="both target_rate_hz and"):
        CompileRequest(specs, 40.0,
                       goal=MinEnergy(rate_hz=30.0)).resolve_goal()


def test_service_frontier_dedups_repeated_deadlines():
    """ParetoFront with duplicate deadlines solves each point once
    (the frontier routes through compile_many's in-batch dedup)."""
    specs = edge_network("squeezenet1.1")
    cfg = OrchestratorConfig(policy="pfdnn", n_max_rails=2)
    d = 1.0 / (max_rate("squeezenet1.1") * 0.8)
    svc = CompileService()
    frontier = svc.compile(specs, cfg=cfg, network="sqz",
                           goal=ParetoFront(deadlines=(d, d, d * 1.5)))
    assert len(frontier.points) == 3
    assert frontier.points[0].schedule.e_total == \
        frontier.points[1].schedule.e_total
    # 3 points, but only 2 distinct solves entered the cache
    assert svc.store.stats()["schedules"] == 2


# ------------------------------------------- schedule artifact fields

def test_goal_fields_survive_schedule_json_roundtrip():
    specs = edge_network("squeezenet1.1")
    rate = max_rate("squeezenet1.1") * 0.9
    cfg = OrchestratorConfig(policy="pfdnn", n_max_rails=2)
    sched = compile_goal(specs, MinEnergy(rate_hz=rate), cfg=cfg,
                         network="sqz")
    back = PowerSchedule.from_json(sched.to_json())
    assert back.goal == sched.goal
    assert back.binding_constraint == "deadline"
    # pre-goal JSON (no goal keys) still loads with defaults
    d = json.loads(sched.to_json())
    d.pop("goal")
    d.pop("binding_constraint")
    old = PowerSchedule.from_json(json.dumps(d))
    assert old.goal is None and old.binding_constraint is None


# ------------------------------------------- mixed-goal compile_many

def test_mixed_goal_compile_many_matches_solo():
    specs_a = edge_network("squeezenet1.1")
    specs_b = edge_network("mobilenetv3-small")
    cfg = OrchestratorConfig(policy="pfdnn", n_max_rails=2)
    rate_a = max_rate("squeezenet1.1") * 0.9
    rate_b = max_rate("mobilenetv3-small") * 0.85
    ref = compile_goal(specs_a, MinEnergy(rate_hz=rate_a), cfg=cfg,
                       network="sqz")
    budget = (ref.e_op + ref.e_trans) * 1.4
    requests = [
        CompileRequest(specs_a, rate_a, cfg, network="sqz"),
        CompileRequest(specs_b, rate_b, cfg, network="mnv3"),
        CompileRequest(specs_a, cfg=cfg, network="sqz",
                       goal=MinLatency(energy_budget_j=budget)),
        CompileRequest(specs_a, cfg=cfg, network="sqz",
                       goal=ParetoFront(n_points=3)),
        CompileRequest(specs_b, cfg=cfg, network="mnv3",
                       goal=MinEnergy(rate_hz=rate_b)),   # dup of [1]
    ]
    svc = CompileService()
    out = svc.compile_many(requests)
    solo_a = compile_power_schedule(specs_a, rate_a, cfg=cfg,
                                    network="sqz")
    solo_b = compile_power_schedule(specs_b, rate_b, cfg=cfg,
                                    network="mnv3")
    solo_dual = compile_goal(specs_a,
                             MinLatency(energy_budget_j=budget),
                             cfg=cfg, network="sqz")
    for got, want in ((out[0], solo_a), (out[1], solo_b),
                      (out[2], solo_dual), (out[4], solo_b)):
        assert got.e_total == want.e_total
        assert got.t_infer == want.t_infer
        assert got.layer_voltages == want.layer_voltages
        assert got.rails == want.rails
    assert isinstance(out[3], ParetoFrontier)
    for p in out[3].points:
        solo = compile_goal(specs_a, MinEnergy(deadline_s=p.deadline_s),
                            cfg=cfg, network="sqz")
        assert p.schedule.e_total == solo.e_total
        assert p.schedule.layer_voltages == solo.layer_voltages
    # the whole batch went through one store; repeats must now be hits
    hits = svc.store.stats()["hits"]["schedule"]
    out2 = svc.compile_many(requests)
    assert svc.store.stats()["hits"]["schedule"] > hits
    assert out2[2].e_total == out[2].e_total


@pytest.mark.skipif("jax" not in BACKENDS, reason="jax not installed")
def test_dual_and_frontier_jax_parity():
    """The dual and frontier solvers are backend-independent: jax
    emits the same schedules as numpy."""
    specs = edge_network("squeezenet1.1")
    rate = max_rate("squeezenet1.1") * 0.5
    ref = compile_goal(specs, MinEnergy(rate_hz=rate),
                       cfg=OrchestratorConfig(policy="pfdnn",
                                              n_max_rails=2),
                       network="sqz")
    budget = (ref.e_op + ref.e_trans) * 1.3
    outs = {}
    for backend in ("numpy", "jax"):
        cfg = OrchestratorConfig(policy="pfdnn", n_max_rails=2,
                                 backend=backend)
        outs[backend] = compile_goal(
            specs, MinLatency(energy_budget_j=budget), cfg=cfg,
            network="sqz")
    assert outs["numpy"].layer_voltages == outs["jax"].layer_voltages
    assert outs["numpy"].t_infer == pytest.approx(outs["jax"].t_infer,
                                                  rel=1e-12)


# ----------------------------------------------- pruning cache parity

def test_pruning_cache_reproduces_uncached_views():
    specs = edge_network("squeezenet1.1")
    ctx = CompilationContext(specs, network="sqz")
    store = ArtifactStore()
    for rails in ((0.9, 1.3), (1.0,), (0.9, 1.1, 1.3)):
        problem = ctx.problem_for(rails, gating=True, allow_sleep=True,
                                  t_max=0.02)
        key = (ctx.content_key, True, rails)
        cold, cold_info = prune_problem(problem)
        miss, miss_info = prune_problem(problem, cache=store,
                                        cache_key=key)
        hit, hit_info = prune_problem(problem, cache=store,
                                      cache_key=key)
        assert cold_info["index_maps"] == miss_info["index_maps"] \
            == hit_info["index_maps"]
        for a, b in ((cold, miss), (cold, hit)):
            assert a.sizes == b.sizes
            for i in range(a.n_layers):
                np.testing.assert_array_equal(a.op_arrays(i)[0],
                                              b.op_arrays(i)[0])
                np.testing.assert_array_equal(a.op_arrays(i)[1],
                                              b.op_arrays(i)[1])
            for i in range(a.n_layers - 1):
                np.testing.assert_array_equal(
                    a.transition_arrays(i)[1],
                    b.transition_arrays(i)[1])
    stats = store.stats()
    assert stats["prunings"] == 3
    assert stats["hits"]["pruning"] == 3
    assert stats["misses"]["pruning"] == 3
