"""Fleet compile service: warm-start parity, cross-network stacking
identity, schedule-cache round-trips, store persistence, and the
concurrent-compile stress test.

The load-bearing property: no matter how a schedule is produced —
cold ``compile_power_schedule``, warm ``CompileService.compile`` on a
pre-populated store, ``compile_many`` with cross-network bucket
stacking on or off, a schedule-cache hit, or a concurrent compile —
the emitted rails, per-layer states, and energies are identical.
"""

import dataclasses
import json
import pathlib
import threading

import pytest

from conftest import max_rate
from repro.core import (
    CompilationContext,
    OrchestratorConfig,
    compile_power_schedule,
)
from repro.core.schedule import PowerSchedule
from repro.hw.dvfs import V_GATED
from repro.models.edge_cnn import edge_network
from repro.service import ArtifactStore, CompileRequest, CompileService

GOLDEN = json.loads(
    (pathlib.Path(__file__).parent / "golden" / "pipeline.json")
    .read_text())


def _assert_same_schedule(a: PowerSchedule, b: PowerSchedule) -> None:
    """Bit-identical deployment artifact: rails, per-layer states,
    energies, and the runtime ledger fields."""
    assert a.rails == b.rails
    assert a.layer_voltages == b.layer_voltages
    assert a.awake_banks == b.awake_banks
    assert a.e_total == b.e_total
    assert a.t_infer == b.t_infer
    assert a.e_op == b.e_op
    assert a.e_trans == b.e_trans
    assert a.e_idle == b.e_idle
    assert a.z_active_idle == b.z_active_idle
    assert a.n_rail_switches == b.n_rail_switches
    assert a.feasible == b.feasible


def _cfg_for(key: str) -> tuple[str, float, OrchestratorConfig]:
    network, frac, n_rails, policy = key.split("|")
    rate = max_rate(network) * float(frac)
    return network, rate, OrchestratorConfig(policy=policy,
                                             n_max_rails=int(n_rails))


@pytest.fixture(scope="module")
def warm_service():
    """A service whose store was populated by compiling every golden
    config once — the fleet steady state every warm test starts from."""
    svc = CompileService()
    first: dict[str, PowerSchedule | None] = {}
    for key in sorted(GOLDEN):
        network, rate, cfg = _cfg_for(key)
        first[key] = svc.compile(edge_network(network), rate, cfg=cfg,
                                 network=network)
    return svc, first


# --------------------------------------------- warm-start parity

@pytest.mark.parametrize("key", sorted(GOLDEN))
def test_warm_solve_parity_golden(key, warm_service):
    """A full solve on a pre-populated store (schedule cache bypassed)
    is bit-identical to a cold compile_power_schedule run."""
    svc, _ = warm_service
    network, rate, cfg = _cfg_for(key)
    cold = compile_power_schedule(edge_network(network), rate, cfg=cfg,
                                  network=network)
    warm_svc = CompileService(store=svc.store, use_schedule_cache=False)
    warm = warm_svc.compile(edge_network(network), rate, cfg=cfg,
                            network=network)
    assert (cold is None) == (warm is None)
    if cold is not None:
        _assert_same_schedule(warm, cold)


@pytest.mark.parametrize("key", sorted(GOLDEN))
def test_schedule_cache_roundtrip_golden(key, warm_service):
    """A schedule-cache hit (to_json → from_json round trip) returns
    the first compile's artifact bit-identically."""
    svc, first = warm_service
    network, rate, cfg = _cfg_for(key)
    hit = svc.compile(edge_network(network), rate, cfg=cfg,
                      network=network)
    assert (first[key] is None) == (hit is None)
    if hit is not None:
        _assert_same_schedule(hit, first[key])
        assert hit.solver_stats == first[key].solver_stats
        assert hit.domains == first[key].domains


@pytest.mark.parametrize(
    "key", [k for k in sorted(GOLDEN) if k.endswith("pfdnn")
            or k.endswith("pfdnn_nopp")])
def test_warm_parity_with_stacking_off(key, warm_service):
    """Warm parity also holds when the subset-stacked engine is
    disabled (legacy per-subset sweep on a warm store)."""
    svc, _ = warm_service
    network, rate, cfg = _cfg_for(key)
    cfg = dataclasses.replace(cfg, stack_subsets=False)
    cold = compile_power_schedule(edge_network(network), rate, cfg=cfg,
                                  network=network)
    warm_svc = CompileService(store=svc.store, use_schedule_cache=False)
    warm = warm_svc.compile(edge_network(network), rate, cfg=cfg,
                            network=network)
    _assert_same_schedule(warm, cold)


# --------------------------------------------- cross-network stacking

def _fleet_requests() -> list[CompileRequest]:
    cfg2 = OrchestratorConfig(policy="pfdnn", n_max_rails=2)
    return [
        CompileRequest(edge_network("squeezenet1.1"),
                       max_rate("squeezenet1.1") * 0.9, cfg2, "sqz"),
        CompileRequest(edge_network("mobilenetv3-small"),
                       max_rate("mobilenetv3-small") * 0.85, cfg2,
                       "mnv3"),
        CompileRequest(edge_network("squeezenet1.1"),
                       max_rate("squeezenet1.1") * 0.5,
                       OrchestratorConfig(policy="pfdnn", n_max_rails=3),
                       "sqz-slow"),
    ]


@pytest.fixture(scope="module")
def solo_fleet_schedules():
    return [compile_power_schedule(r.specs, r.target_rate_hz, cfg=r.cfg,
                                   network=r.network)
            for r in _fleet_requests()]


@pytest.mark.parametrize("stack_networks", [True, False])
def test_compile_many_matches_solo(stack_networks, solo_fleet_schedules):
    """compile_many over ≥3 deployment points — with and without
    cross-network stacking — emits exactly the solo schedules."""
    svc = CompileService()
    many = svc.compile_many(_fleet_requests(),
                            stack_networks=stack_networks)
    assert len(many) == 3
    for got, ref in zip(many, solo_fleet_schedules):
        _assert_same_schedule(got, ref)
    if stack_networks:
        # the sweeps really were co-scheduled in one round scheduler
        assert all(s.solver_stats.get("fleet_networks") == 3
                   for s in many)


def test_compile_many_dedups_and_caches(solo_fleet_schedules):
    reqs = _fleet_requests()
    # append an in-batch duplicate of request 0 under another label
    dup = CompileRequest(reqs[0].specs, reqs[0].target_rate_hz,
                         reqs[0].cfg, "sqz-copy")
    svc = CompileService()
    many = svc.compile_many(reqs + [dup])
    _assert_same_schedule(many[3], solo_fleet_schedules[0])
    assert many[3].network == "sqz-copy"
    # repeat traffic: the whole batch answers from the schedule cache
    before = svc.store.stats()["hits"]["schedule"]
    again = svc.compile_many(reqs)
    assert svc.store.stats()["hits"]["schedule"] == before + 3
    for got, ref in zip(again, solo_fleet_schedules):
        _assert_same_schedule(got, ref)


def test_infeasible_point_is_cached():
    specs = edge_network("squeezenet1.1")
    rate = max_rate("squeezenet1.1") * 2.0        # beyond max rate
    svc = CompileService()
    cfg = OrchestratorConfig(policy="pfdnn", n_max_rails=2)
    assert svc.compile(specs, rate, cfg=cfg) is None
    before = svc.store.stats()["hits"]["schedule"]
    assert svc.compile(specs, rate, cfg=cfg) is None
    assert svc.store.stats()["hits"]["schedule"] == before + 1


# --------------------------------------------- concurrent compiles

def test_threaded_compile_many_stress(solo_fleet_schedules):
    """Two threads drive overlapping compile_many batches through ONE
    service (same accelerator, overlapping buckets): every result must
    equal the solo compile, and the shared store must stay coherent."""
    svc = CompileService(use_schedule_cache=False)   # force full solves
    reqs = _fleet_requests()
    orders = [[0, 1, 2], [2, 0, 1]]
    results: dict[int, list] = {}
    errors: list[BaseException] = []

    def run(tid: int, order: list[int]) -> None:
        try:
            out = svc.compile_many([reqs[i] for i in order])
            results[tid] = [out[order.index(i)] for i in range(3)]
        except BaseException as exc:             # surface in main thread
            errors.append(exc)

    threads = [threading.Thread(target=run, args=(t, o))
               for t, o in enumerate(orders)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors, errors
    for tid in range(2):
        for got, ref in zip(results[tid], solo_fleet_schedules):
            _assert_same_schedule(got, ref)
    # overlapping buckets really were shared (lanes resident once)
    assert svc.store.stats()["resident_lanes"] > 0


# --------------------------------------------- store persistence

def test_store_save_load_roundtrip(tmp_path):
    specs = edge_network("squeezenet1.1")
    rate = max_rate("squeezenet1.1") * 0.9
    cfg = OrchestratorConfig(policy="pfdnn", n_max_rails=2)
    svc = CompileService()
    ref = svc.compile(specs, rate, cfg=cfg, network="sqz")
    path = tmp_path / "artifacts.npz"
    svc.save(path)

    loaded = CompileService(store=ArtifactStore().load(path))
    stats = loaded.store.stats()
    assert stats["schedules"] >= 1
    assert stats["masters"] >= 1
    assert stats["transitions"] >= 1
    # schedule-cache hit straight from disk
    hit = loaded.compile(specs, rate, cfg=cfg, network="sqz")
    _assert_same_schedule(hit, ref)
    # warm full solve from the persisted tables
    loaded.store.clear(schedules=True, stacks=False, tables=False)
    warm = loaded.compile(specs, rate, cfg=cfg, network="sqz")
    _assert_same_schedule(warm, ref)


def test_store_trim_and_clear_stay_correct():
    specs = edge_network("squeezenet1.1")
    rate = max_rate("squeezenet1.1") * 0.9
    cfg = OrchestratorConfig(policy="pfdnn", n_max_rails=2)
    svc = CompileService(use_schedule_cache=False)
    ref = svc.compile(specs, rate, cfg=cfg, network="sqz")
    assert svc.store.stats()["resident_lanes"] > 0
    assert svc.trim(max_lanes=0)                  # force a lane reset
    assert svc.store.stats()["resident_lanes"] == 0
    again = svc.compile(specs, rate, cfg=cfg, network="sqz")
    _assert_same_schedule(again, ref)
    svc.store.clear()
    assert svc.store.stats()["schedules"] == 0
    _assert_same_schedule(
        svc.compile(specs, rate, cfg=cfg, network="sqz"), ref)


# --------------------------------------------- ctx= reuse (satellite)

def test_compile_with_prebuilt_ctx_reuses_characterization(monkeypatch):
    import repro.core.context as context_mod

    calls = {"n": 0}
    real = context_mod.characterize_network

    def counting(specs, acc):
        calls["n"] += 1
        return real(specs, acc)

    monkeypatch.setattr(context_mod, "characterize_network", counting)
    specs = edge_network("squeezenet1.1")
    rate = max_rate("squeezenet1.1") * 0.9
    ctx = CompilationContext(specs, rate, network="sqz")
    assert calls["n"] == 1
    ref_pf = compile_power_schedule(
        specs, rate, cfg=OrchestratorConfig(policy="pfdnn",
                                            n_max_rails=2))
    ref_gr = compile_power_schedule(
        specs, rate, cfg=OrchestratorConfig(policy="greedy_gating",
                                            n_max_rails=2))
    calls["n"] = 0
    via_ctx_pf = compile_power_schedule(
        specs, rate, cfg=OrchestratorConfig(policy="pfdnn",
                                            n_max_rails=2), ctx=ctx)
    via_ctx_gr = compile_power_schedule(
        specs, rate, cfg=OrchestratorConfig(policy="greedy_gating",
                                            n_max_rails=2), ctx=ctx)
    assert calls["n"] == 0        # no silent re-characterization
    _assert_same_schedule(via_ctx_pf, ref_pf)
    _assert_same_schedule(via_ctx_gr, ref_gr)


def test_compile_with_mismatched_ctx_raises():
    specs = edge_network("squeezenet1.1")
    rate = max_rate("squeezenet1.1") * 0.9
    ctx = CompilationContext(specs, rate, network="sqz")
    # the goal API decoupled the context from a single deadline: one
    # context now serves every rate of its network, and compiling at a
    # different rate through it matches a fresh compile exactly
    cfg = OrchestratorConfig(policy="pfdnn", n_max_rails=2)
    via_ctx = compile_power_schedule(specs, rate * 0.5, cfg=cfg, ctx=ctx)
    fresh = compile_power_schedule(specs, rate * 0.5, cfg=cfg,
                                   network="sqz")
    _assert_same_schedule(via_ctx, fresh)
    with pytest.raises(ValueError, match="different network"):
        compile_power_schedule(edge_network("mobilenetv3-small"), rate,
                               ctx=ctx)
    with pytest.raises(ValueError, match="e_switch_nom"):
        compile_power_schedule(
            specs, rate, cfg=OrchestratorConfig(e_switch_nom=5e-9),
            ctx=ctx)
    with pytest.raises(ValueError, match="network label"):
        compile_power_schedule(specs, rate, network="other", ctx=ctx)
    with pytest.raises(ValueError, match="store"):
        compile_power_schedule(specs, rate, ctx=ctx,
                               store=ArtifactStore())
    # matching label (or omitting it) is fine
    assert compile_power_schedule(
        specs, rate, cfg=OrchestratorConfig(policy="baseline"),
        network="sqz", ctx=ctx) is not None


# ------------------------------------- PowerSchedule JSON round-trips

def test_schedule_json_roundtrip_gated_states_and_ledger():
    """Hand-built schedule with gated (0.0) states, non-representable
    float rails, and every ledger field: two round trips must be exact
    (the persistent schedule cache depends on this)."""
    sched = PowerSchedule(
        policy="pfdnn",
        network="unit",
        rails=(0.1 + 0.2, 0.95, 1.3),            # 0.30000000000000004
        layer_voltages=[(1.3, 1.3, 1.3), (0.95, 0.95, V_GATED),
                        (0.1 + 0.2, 0.95, 0.95)],
        awake_banks=[16, 0, 7],
        t_max=1.0 / 3.0,
        t_infer=0.123456789012345678,
        e_total=1.0000000000000002e-6,
        e_op=9.999999999999999e-7,
        e_trans=1.5e-13,
        e_idle=4.9e-14,
        z_active_idle=0,
        n_rail_switches=2,
        feasible=True,
        solver_stats={"dp_calls": 17, "lambda_star": 0.007,
                      "nested": {"wall_time_s": 0.25}},
    )
    once = PowerSchedule.from_json(sched.to_json())
    twice = PowerSchedule.from_json(once.to_json())
    for restored in (once, twice):
        assert restored == sched                  # full dataclass equality
        assert isinstance(restored.rails, tuple)
        assert isinstance(restored.domains, tuple)
        assert all(isinstance(v, tuple)
                   for v in restored.layer_voltages)
        assert restored.layer_voltages[1][2] == V_GATED
        assert restored.solver_stats["nested"]["wall_time_s"] == 0.25
    assert once.program() == sched.program()
    assert once.slack == sched.slack


@pytest.mark.parametrize("policy", ["pfdnn", "greedy_gating",
                                    "baseline"])
def test_schedule_json_roundtrip_compiled(policy):
    """Compiled artifacts (solver_stats included) survive the round
    trip with full equality."""
    specs = edge_network("squeezenet1.1")
    rate = max_rate("squeezenet1.1") * 0.9
    sched = compile_power_schedule(
        specs, rate, cfg=OrchestratorConfig(policy=policy,
                                            n_max_rails=2),
        network="sqz")
    restored = PowerSchedule.from_json(sched.to_json())
    assert restored == sched
    _assert_same_schedule(restored, sched)
