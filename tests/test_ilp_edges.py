"""Edge-case coverage for the exact ILP oracles (``repro.core.ilp``):
solver-failure paths (time limit, infeasible model), degenerate
instances (single layer), and the variable-budget blowup guard."""

import numpy as np
import pytest

from conftest import random_problem
from repro.core.ilp import IlpBlowupError, solve_ilp, solve_ilp_min_latency


@pytest.fixture
def rng():
    return np.random.default_rng(7)


def test_time_limit_returns_failure_dict(rng):
    problem = random_problem(rng, n_layers=12, n_states=6)
    res = solve_ilp(problem, time_limit=1e-9)
    assert res["feasible"] is False
    assert res["status"] == 1                 # HiGHS: limit reached
    assert "Time limit" in res["message"]
    assert res["wall_time_s"] >= 0.0
    # no partial evaluation keys leak out of the failure path
    assert "e_total" not in res and "path" not in res


def test_infeasible_deadline(rng):
    problem = random_problem(rng, n_layers=4, n_states=3,
                             t_max_scale=1e-6)
    res = solve_ilp(problem)
    assert res["feasible"] is False
    assert res["status"] == 2                 # proven infeasible
    assert "wall_time_s" in res and "message" in res


def test_single_layer_matches_brute_force(rng):
    problem = random_problem(rng, n_layers=1, n_states=5)
    res = solve_ilp(problem)
    assert res["feasible"] is True
    # no transitions on a single layer; the optimum is the cheapest
    # deadline-holding state, which brute force finds directly
    best = min(
        (problem.evaluate([s]) for s in range(len(problem.layer_states[0]))
         if problem.evaluate([s])["feasible"]),
        key=lambda r: r["e_total"])
    assert res["e_total"] == pytest.approx(best["e_total"], rel=1e-6)
    assert res["e_trans"] == 0.0
    assert res["n_variables"] >= len(problem.layer_states[0])


def test_blowup_guard(rng):
    problem = random_problem(rng, n_layers=12, n_states=6)
    with pytest.raises(IlpBlowupError, match="variables"):
        solve_ilp(problem, max_variables=10)
    # the message reports the layered-graph arithmetic
    with pytest.raises(IlpBlowupError, match=r"Σ\|S_i\|"):
        solve_ilp_min_latency(problem, budget=1.0, max_variables=10)


def test_min_latency_budget_infeasible(rng):
    problem = random_problem(rng, n_layers=3, n_states=4)
    res = solve_ilp_min_latency(problem, budget=1e-12)
    assert res["feasible"] is False
    assert res["status"] == 2
    assert "wall_time_s" in res


def test_min_latency_generous_budget_is_fastest_path(rng):
    problem = random_problem(rng, n_layers=3, n_states=4)
    res = solve_ilp_min_latency(problem, budget=1.0)
    assert res["feasible"] is True
    # with the budget slack, the optimum is the unconstrained
    # min-time path; lower-bound it by the sum of per-layer minima
    t_floor = sum(min(s.t_op for s in states)
                  for states in problem.layer_states)
    assert res["t_infer"] >= t_floor - 1e-12
    assert res["ilp_objective"] == pytest.approx(res["t_infer"],
                                                 rel=1e-6)


def test_min_latency_single_layer(rng):
    problem = random_problem(rng, n_layers=1, n_states=4)
    res = solve_ilp_min_latency(problem, budget=1.0)
    assert res["feasible"] is True
    t_best = min(s.t_op for s in problem.layer_states[0])
    assert res["t_infer"] == pytest.approx(t_best, rel=1e-9)


def test_ilp_matches_brute_force_small(rng):
    """Exactness sanity on an enumerable instance: the ILP optimum
    equals exhaustive search over every layered path."""
    import itertools

    problem = random_problem(rng, n_layers=3, n_states=3)
    res = solve_ilp(problem)
    evals = [problem.evaluate(list(p))
             for p in itertools.product(range(3), repeat=3)]
    feas = [e for e in evals if e["feasible"]]
    if not feas:
        assert res["feasible"] is False
        return
    best = min(e["e_total"] for e in feas)
    assert res["feasible"] is True
    assert res["e_total"] == pytest.approx(best, rel=1e-6)
