"""Multi-device sharding tests — each runs in a SUBPROCESS with its own
XLA_FLAGS so the main test process keeps seeing exactly 1 device."""

import json
import os
import pathlib
import subprocess
import sys
import textwrap

import pytest

REPO = pathlib.Path(__file__).resolve().parents[1]

# These tests drive jax.make_mesh(..., axis_types=jax.sharding.AxisType...)
# in subprocesses; older jax releases predate that API, and the failures
# are a toolchain property, not a regression in this repo's code.
import jax  # noqa: E402

if not hasattr(jax.sharding, "AxisType"):
    pytestmark = pytest.mark.skip(
        reason="installed jax lacks jax.sharding.AxisType "
               "(needs a newer jax than this environment ships)")


def run_subprocess(code: str, n_devices: int = 8, timeout: int = 480):
    env = dict(os.environ)
    env["XLA_FLAGS"] = (f"--xla_force_host_platform_device_count="
                        f"{n_devices}")
    env["PYTHONPATH"] = str(REPO / "src")
    proc = subprocess.run([sys.executable, "-c", code],
                          capture_output=True, text=True, env=env,
                          timeout=timeout)
    assert proc.returncode == 0, \
        f"STDOUT:\n{proc.stdout}\nSTDERR:\n{proc.stderr[-3000:]}"
    return proc.stdout


def test_moe_ep_matches_dense_oracle():
    """shard_map expert-parallel MoE == dense gather oracle (4 devices,
    no-drop capacity)."""
    out = run_subprocess(textwrap.dedent("""
        import warnings; warnings.filterwarnings("ignore")
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P, NamedSharding
        from repro.models import moe as moe_lib

        mesh = jax.make_mesh((2, 2), ("data", "model"),
                             axis_types=(jax.sharding.AxisType.Auto,)*2)
        dims = moe_lib.MoeDims(n_experts=8, top_k=2, d_model=16,
                               d_ff=32, capacity_factor=8.0)
        k = jax.random.split(jax.random.PRNGKey(0), 5)
        b, s = 4, 8
        x = jax.random.normal(k[0], (b, s, 16), jnp.float32)
        wr = jax.random.normal(k[1], (16, 8)) * 0.1
        w1 = jax.random.normal(k[2], (8, 16, 32))
        w3 = jax.random.normal(k[3], (8, 16, 32))
        w2 = jax.random.normal(k[4], (8, 32, 16))
        dense = moe_lib.moe_ffn_dense(
            x.reshape(-1, 16), wr, w1, w3, w2, dims).reshape(b, s, 16)
        with mesh:
            xs = jax.device_put(x, NamedSharding(mesh, P("data", None, None)))
            w1s = jax.device_put(w1, NamedSharding(mesh, P("data", None, "model")))
            w3s = jax.device_put(w3, NamedSharding(mesh, P("data", None, "model")))
            w2s = jax.device_put(w2, NamedSharding(mesh, P("data", "model", None)))
            ep = jax.jit(lambda *a: moe_lib.moe_ffn_ep(
                *a, dims, mesh, batch_axes=("data",)))(xs, wr, w1s, w3s, w2s)
        err = float(jnp.max(jnp.abs(np.asarray(ep) - np.asarray(dense))))
        print("err", err)
        assert err < 2e-4, err
        print("EP-OK")
    """), n_devices=4)
    assert "EP-OK" in out


def test_train_step_shards_on_8_devices():
    """Reduced model train step lowers, compiles AND RUNS on a 4x2 mesh
    with the production sharding rules; loss finite."""
    out = run_subprocess(textwrap.dedent("""
        import warnings; warnings.filterwarnings("ignore")
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs import get_config
        from repro.models.transformer import Runtime, init_params
        from repro.train.optimizer import AdamWConfig, adamw_init
        from repro.train.trainer import TrainConfig, make_train_step

        mesh = jax.make_mesh((4, 2), ("data", "model"),
                             axis_types=(jax.sharding.AxisType.Auto,)*2)
        cfg = get_config("qwen2-7b").reduced(
            d_model=64, n_heads=4, n_kv_heads=2, d_ff=64)
        rt = Runtime(mesh=mesh)
        params, specs = init_params(cfg, jax.random.PRNGKey(0))
        ocfg = AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=5)
        opt, _ = adamw_init(params, specs, ocfg)
        with mesh:
            shard = jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                                 is_leaf=lambda s: isinstance(s, P))
            params = jax.tree.map(jax.device_put, params, shard)
            toks = jax.random.randint(jax.random.PRNGKey(1), (8, 16), 0,
                                      cfg.vocab_size)
            batch = {"tokens": jax.device_put(
                         toks, NamedSharding(mesh, P("data", None))),
                     "labels": jax.device_put(
                         toks, NamedSharding(mesh, P("data", None)))}
            step = jax.jit(make_train_step(cfg, TrainConfig(optimizer=ocfg), rt))
            p2, o2, m = step(params, opt, batch)
            print("loss", float(m["loss"]))
            assert jnp.isfinite(m["loss"])
        print("SHARD-OK")
    """), n_devices=8)
    assert "SHARD-OK" in out


def test_sharded_loss_matches_single_device():
    """Distribution must not change the math: same loss on 1 vs 8
    devices (same params, same batch)."""
    code = textwrap.dedent("""
        import warnings; warnings.filterwarnings("ignore")
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs import get_config
        from repro.models.transformer import Runtime, init_params, forward_train

        cfg = get_config("tinyllama-1.1b").reduced()
        params, specs = init_params(cfg, jax.random.PRNGKey(0))
        toks = jax.random.randint(jax.random.PRNGKey(1), (8, 16), 0,
                                  cfg.vocab_size)
        batch = {"tokens": toks, "labels": toks}
        if len(jax.devices()) > 1:
            mesh = jax.make_mesh((4, 2), ("data", "model"),
                                 axis_types=(jax.sharding.AxisType.Auto,)*2)
            rt = Runtime(mesh=mesh)
            with mesh:
                sh = jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                                  is_leaf=lambda s: isinstance(s, P))
                params = jax.tree.map(jax.device_put, params, sh)
                loss = jax.jit(lambda p, b: forward_train(p, cfg, b, rt))(
                    params, batch)
        else:
            loss = forward_train(params, cfg, batch, Runtime())
        print("LOSS", float(loss))
    """)
    out1 = run_subprocess(code, n_devices=1)
    out8 = run_subprocess(code, n_devices=8)
    l1 = float(out1.split("LOSS")[1].strip())
    l8 = float(out8.split("LOSS")[1].strip())
    assert abs(l1 - l8) / abs(l1) < 2e-2, (l1, l8)


def test_dryrun_mini_mesh_cell():
    """The dry-run machinery itself (lower+compile+analyses) on a small
    in-process mesh via a subprocess — the multi-pod smoke."""
    out = run_subprocess(textwrap.dedent("""
        import warnings; warnings.filterwarnings("ignore")
        import os
        os.environ.setdefault("XLA_FLAGS", "")
        import jax
        from repro.launch.dryrun import parse_collective_bytes
        from jax.sharding import NamedSharding, PartitionSpec as P
        import jax.numpy as jnp
        mesh = jax.make_mesh((2, 4), ("data", "model"),
                             axis_types=(jax.sharding.AxisType.Auto,)*2)
        def f(x, w):
            return jnp.sum(jnp.tanh(x @ w))
        xs = jax.ShapeDtypeStruct((16, 32), jnp.bfloat16)
        ws = jax.ShapeDtypeStruct((32, 64), jnp.bfloat16)
        with mesh:
            lowered = jax.jit(f, in_shardings=(
                NamedSharding(mesh, P("data", None)),
                NamedSharding(mesh, P(None, "model")))).lower(xs, ws)
            compiled = lowered.compile()
            ma = compiled.memory_analysis()
            assert ma.peak_memory_in_bytes > 0
            coll = parse_collective_bytes(compiled.as_text())
            assert coll["bytes_per_device_total"] > 0
        print("DRYRUN-OK")
    """), n_devices=8)
    assert "DRYRUN-OK" in out
