"""Hypothesis property tests on the system's invariants."""

import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="hypothesis not installed (see requirements-dev.txt)")
from hypothesis import given, settings, strategies as st  # noqa: E402

from conftest import random_problem
from repro.core import (
    prune_problem,
    refine_candidates,
    solve_lambda_dp,
)
from repro.core.problem import IdleModel
from repro.hw.dvfs import DvfsModel, TransitionModel, voltage_levels


# ---------------------------------------------------------------- DVFS

@given(v=st.floats(0.5, 1.4))
@settings(max_examples=50, deadline=None)
def test_dvfs_frequency_monotone(v):
    m = DvfsModel()
    f1, f2 = m.freq(v), m.freq(v + 0.05)
    assert f2 >= f1 >= 0


@given(v=st.floats(0.5, 1.4))
@settings(max_examples=50, deadline=None)
def test_leakage_monotone_and_gated_zero(v):
    m = DvfsModel()
    assert m.leak_power(0.0) == 0.0
    assert m.leak_power(v + 0.05) >= m.leak_power(v) >= 0


@given(a=st.floats(0.7, 1.3), b=st.floats(0.7, 1.3))
@settings(max_examples=50, deadline=None)
def test_transition_energy_symmetric_latency_positive(a, b):
    tm = TransitionModel()
    assert tm.energy(a, b) == tm.energy(b, a)
    assert tm.latency(a, b) >= 0
    if abs(a - b) > 1e-12:
        assert tm.energy(a, b) > 0
    assert tm.energy(a, a) == 0 and tm.latency(a, a) == 0


def test_voltage_levels_exact():
    levels = voltage_levels(0.9, 1.3, 0.05)
    assert len(levels) == 9
    assert levels[0] == 0.9 and levels[-1] == 1.3


# ---------------------------------------------------------- idle model

@given(slack=st.floats(0, 1.0))
@settings(max_examples=50, deadline=None)
def test_idle_energy_nonneg_and_bounded_by_active(slack):
    idle = IdleModel(p_idle=1e-3, p_sleep=1e-5, e_sleep_wake=1e-7,
                     t_sleep_wake=1e-6)
    e = idle.energy(slack)
    assert 0 <= e <= 1e-3 * slack + 1e-12


# ------------------------------------------------------------- solvers

@given(seed=st.integers(0, 10_000))
@settings(max_examples=25, deadline=None)
def test_dp_beats_random_feasible_schedules(seed):
    rng = np.random.default_rng(seed)
    prob = random_problem(rng, n_layers=5, n_states=4)
    best, cands, _ = solve_lambda_dp(prob)
    refined = None
    if cands:
        refined, _ = refine_candidates(prob, cands)
    found_feasible = False
    for _ in range(50):
        path = [int(rng.integers(len(s))) for s in prob.layer_states]
        r = prob.evaluate(path)
        if r["feasible"]:
            found_feasible = True
            assert refined is not None, \
                "solver missed a feasible schedule entirely"
            assert refined["e_total"] <= r["e_total"] + 1e-15
    if found_feasible:
        assert best is not None


@given(seed=st.integers(0, 10_000))
@settings(max_examples=20, deadline=None)
def test_pruning_never_changes_solution_energy(seed):
    rng = np.random.default_rng(seed)
    prob = random_problem(rng, n_layers=4, n_states=6)
    pruned, info = prune_problem(prob)
    assert info["states_after"] <= info["states_before"]
    b1, c1, _ = solve_lambda_dp(prob)
    b2, c2, _ = solve_lambda_dp(pruned)
    assert (b1 is None) == (b2 is None)
    if b1 is None:
        return
    r1, _ = refine_candidates(prob, c1)
    r2, _ = refine_candidates(pruned, c2)
    assert abs(r2["e_total"] - r1["e_total"]) <= 1e-9 * max(
        r1["e_total"], 1e-30) + 1e-15


@given(seed=st.integers(0, 10_000))
@settings(max_examples=20, deadline=None)
def test_looser_deadline_never_costs_more(seed):
    rng = np.random.default_rng(seed)
    prob = random_problem(rng, n_layers=4, n_states=4,
                          allow_sleep=True)
    import dataclasses

    loose = dataclasses.replace(prob)
    loose = type(prob)(layer_states=prob.layer_states,
                       t_max=prob.t_max * 1.5, idle=prob.idle,
                       transition_model=prob.transition_model)
    b1, c1, _ = solve_lambda_dp(prob)
    b2, c2, _ = solve_lambda_dp(loose)
    if b1 is None:
        return
    r1, _ = refine_candidates(prob, c1)
    r2, _ = refine_candidates(loose, c2)
    # with duty-cycled sleep available, extra slack is never harmful
    # beyond the (tiny) sleep retention cost on the added interval
    extra_floor = prob.idle.p_sleep * prob.t_max * 0.5
    assert r2["e_total"] <= r1["e_total"] + extra_floor + 1e-12


@given(seed=st.integers(0, 10_000))
@settings(max_examples=25, deadline=None)
def test_evaluate_consistency(seed):
    """e_total decomposes exactly; feasibility flag matches t_infer."""
    rng = np.random.default_rng(seed)
    prob = random_problem(rng, n_layers=5, n_states=3)
    path = [int(rng.integers(len(s))) for s in prob.layer_states]
    r = prob.evaluate(path)
    assert r["e_total"] == r["e_op"] + r["e_trans"] + r["e_idle"]
    assert r["feasible"] == (r["t_infer"] <= prob.t_max + 1e-15)
    assert r["n_rail_switches"] <= prob.n_layers - 1


# --------------------------------------- batched multi-λ DP engine

@given(seed=st.integers(0, 10_000), n_layers=st.integers(2, 7),
       n_states=st.integers(2, 6))
@settings(max_examples=30, deadline=None)
def test_dp_multi_matches_per_lambda_scalar(seed, n_layers, n_states):
    """Every row of the batched DP equals the scalar DP at that λ."""
    from repro.core import dp_best_path, dp_paths_multi

    rng = np.random.default_rng(seed)
    prob = random_problem(rng, n_layers=n_layers, n_states=n_states)
    mus = [0.0, -prob.idle.p_sleep, 1e-3, 0.7, 50.0]
    multi = dp_paths_multi(prob, mus)
    for j, mu in enumerate(mus):
        assert list(multi[j]) == dp_best_path(prob, mu)


@given(seed=st.integers(0, 10_000), tight=st.booleans())
@settings(max_examples=30, deadline=None, derandomize=True)
def test_batched_bisection_matches_scalar_bisection(seed, tight):
    """The batched λ search and the legacy scalar bisection agree on
    feasibility and select the same schedule energy.

    Derandomized: the two candidate pools are not structurally forced
    to coincide (the batched grid can discover a strictly better
    schedule — that is a feature), so this pins a fixed example set
    rather than gambling fresh draws in CI; a failure here is a real,
    reproducible behaviour change.
    """
    rng = np.random.default_rng(seed)
    prob = random_problem(rng, n_layers=5, n_states=4,
                          t_max_scale=0.9 if tight else 1.0)
    b1, _, s1 = solve_lambda_dp(prob, batch_lambda=True)
    b2, _, s2 = solve_lambda_dp(prob, batch_lambda=False)
    assert (b1 is None) == (b2 is None)
    if b1 is not None:
        assert abs(b1["e_total"] - b2["e_total"]) \
            <= 1e-9 * b2["e_total"]
        assert s1.dp_calls < s2.dp_calls


@given(seed=st.integers(0, 10_000))
@settings(max_examples=15, deadline=None)
def test_parallel_rail_selection_matches_serial(seed):
    """Randomized subset costs with ties: the thread-pool sweep selects
    exactly the subset the sequential sweep selects."""
    from repro.core import select_rails

    from repro.core import all_rail_subsets

    rng = np.random.default_rng(seed)
    levels = [0.9, 0.95, 1.0, 1.1, 1.2]
    # infeasibility must be monotone in max(subset) — the dominance
    # ceiling's premise (voltage headroom).  Energies are fixed up
    # front so completion order can't perturb the draws; quantization
    # produces ties.
    v_need = float(rng.choice(levels + [0.0]))
    costs = {s: round(float(rng.integers(1, 5)), 3)
             for s in all_rail_subsets(levels, 2)}

    def solve(subset, hint=None):
        if max(subset) < v_need:
            return None
        return {"e_total": costs[subset]}

    serial = select_rails(levels, 2, solve)
    parallel = select_rails(levels, 2, solve, workers=3)
    assert parallel[1] == serial[1]
    if serial[0] is not None:
        assert parallel[0]["e_total"] == serial[0]["e_total"]
