"""Shared fixtures. NOTE: no XLA_FLAGS here — smoke tests must see the
single real CPU device; multi-device sharding tests spawn subprocesses
with their own flags (tests/test_sharding.py)."""

import numpy as np
import pytest

from repro.core.problem import IdleModel, ScheduleProblem, StateCost
from repro.hw.dvfs import TransitionModel
from repro.hw.edge40nm import EDGE40NM_DEFAULT
from repro.models.edge_cnn import edge_network
from repro.perfmodel import characterize_network


def max_rate(name: str, acc=EDGE40NM_DEFAULT) -> float:
    """Max feasible inference rate = 1 / latency with all domains at
    V_max (the fastest any schedule can run).  Golden keys and operating
    points are derived from this — keep it the single test-side copy."""
    costs = characterize_network(edge_network(name), acc)
    fs = [acc.dvfs(d).freq(acc.v_max) for d in range(3)]
    t = sum(max(cy / f for cy, f in zip(c.cycles, fs)) for c in costs)
    return 1.0 / t


def random_problem(rng: np.random.Generator, *, n_layers: int,
                   n_states: int, t_max_scale: float = 1.0,
                   allow_sleep: bool = True) -> ScheduleProblem:
    """Random-but-valid layered problem for property tests."""
    layers = []
    volt_menu = [0.7, 0.8, 0.9, 1.0, 1.1]
    for _ in range(n_layers):
        states = []
        for _ in range(n_states):
            v = tuple(rng.choice(volt_menu, size=3))
            t = float(rng.uniform(1e-5, 1e-3))
            e = float(rng.uniform(1e-7, 1e-4))
            states.append(StateCost(v, t, e))
        layers.append(states)
    min_t = sum(min(s.t_op for s in states) for states in layers)
    max_t = sum(max(s.t_op for s in states) for states in layers)
    t_max = float(min_t + (max_t - min_t) * rng.uniform(0.1, 1.2))
    t_max *= t_max_scale
    idle = IdleModel(p_idle=float(rng.uniform(1e-4, 1e-2)),
                     p_sleep=float(rng.uniform(1e-6, 1e-4)),
                     e_sleep_wake=float(rng.uniform(1e-9, 1e-7)),
                     t_sleep_wake=1e-6,
                     allow_sleep=allow_sleep)
    return ScheduleProblem(
        layer_states=layers, t_max=t_max, idle=idle,
        transition_model=TransitionModel(v_min=0.7, v_max=1.1))


@pytest.fixture
def rng():
    return np.random.default_rng(0)
