"""Solver correctness: λ-DP vs brute force, ILP agreement, refinement,
pruning identity, greedy semantics (paper §4.3, §6.5)."""

import itertools

import numpy as np
import pytest

from conftest import random_problem
from repro.core import (
    build_edge_problem,
    dp_best_path,
    min_energy_path,
    min_time_path,
    prune_problem,
    refine_candidates,
    solve_greedy,
    solve_ilp,
    solve_lambda_dp,
    unprune_path,
)
from repro.hw.edge40nm import EDGE40NM_DEFAULT as ACC
from repro.models.edge_cnn import edge_network
from repro.perfmodel import characterize_network, plan_banks


def brute_force(problem):
    """Exact optimum by enumeration (tiny instances only)."""
    best = None
    sizes = [range(len(s)) for s in problem.layer_states]
    for path in itertools.product(*sizes):
        r = problem.evaluate(list(path))
        if r["feasible"] and (best is None
                              or r["e_total"] < best["e_total"]):
            best = r
    return best


@pytest.mark.parametrize("seed", range(6))
def test_lambda_dp_refine_matches_brute_force(seed):
    rng = np.random.default_rng(seed)
    prob = random_problem(rng, n_layers=4, n_states=4)
    exact = brute_force(prob)
    best, cands, _ = solve_lambda_dp(prob)
    if exact is None:
        assert best is None
        return
    assert best is not None
    refined, _ = refine_candidates(prob, cands)
    gap = refined["e_total"] / exact["e_total"] - 1
    assert gap <= 5e-3, f"refined gap {gap:.4%} vs brute force"


@pytest.mark.parametrize("seed", range(4))
def test_ilp_matches_brute_force(seed):
    rng = np.random.default_rng(100 + seed)
    prob = random_problem(rng, n_layers=4, n_states=3)
    exact = brute_force(prob)
    ilp = solve_ilp(prob)
    if exact is None:
        assert not ilp.get("feasible")
        return
    assert ilp["feasible"]
    assert ilp["e_total"] == pytest.approx(exact["e_total"], rel=1e-6)


def test_refinement_never_worse():
    rng = np.random.default_rng(7)
    for _ in range(5):
        prob = random_problem(rng, n_layers=6, n_states=5)
        best, cands, _ = solve_lambda_dp(prob)
        if best is None:
            continue
        refined, _ = refine_candidates(prob, cands)
        assert refined["e_total"] <= best["e_total"] + 1e-18
        assert refined["feasible"]


def test_pruning_preserves_solution_on_edge_networks():
    specs = edge_network("squeezenet1.1")
    costs = characterize_network(specs, ACC)
    plan = plan_banks(costs, ACC)
    for rate in (60.0, 30.0, 10.0):
        prob = build_edge_problem(costs, plan, ACC, (0.9, 1.05, 1.2),
                                  1.0 / rate)
        pruned, info = prune_problem(prob)
        assert info["states_after"] < info["states_before"]
        b1, c1, _ = solve_lambda_dp(prob)
        b2, c2, _ = solve_lambda_dp(pruned)
        r1, _ = refine_candidates(prob, c1)
        r2, _ = refine_candidates(pruned, c2)
        # identical schedules (paper §6.5): same energy to fp precision
        assert r2["e_total"] == pytest.approx(r1["e_total"], rel=1e-9)
        # and the unpruned path indices map back consistently
        orig = unprune_path(r2["path"], info["index_maps"])
        assert prob.evaluate(orig)["e_total"] == pytest.approx(
            r2["e_total"], rel=1e-9)


def test_min_time_and_min_energy_paths_bracket_dp():
    rng = np.random.default_rng(11)
    prob = random_problem(rng, n_layers=5, n_states=4)
    fastest = prob.evaluate(min_time_path(prob))
    cheapest_ops = min_energy_path(prob)
    best, _, _ = solve_lambda_dp(prob)
    if best is not None:
        assert best["t_infer"] >= fastest["t_infer"] - 1e-15
        e_floor = sum(prob.op_arrays(i)[1][s]
                      for i, s in enumerate(cheapest_ops))
        assert best["e_op"] >= e_floor - 1e-18


def test_greedy_meets_deadline_or_returns_none():
    rng = np.random.default_rng(21)
    for _ in range(8):
        prob = random_problem(rng, n_layers=6, n_states=4)
        r = solve_greedy(prob)
        fastest = prob.evaluate(min_time_path(prob))
        if fastest["feasible"]:
            assert r is not None and r["feasible"]
        else:
            assert r is None


def test_infeasible_deadline_returns_none():
    rng = np.random.default_rng(33)
    prob = random_problem(rng, n_layers=4, n_states=3,
                          t_max_scale=1e-6)
    best, cands, _ = solve_lambda_dp(prob)
    assert best is None and cands == []
    assert solve_greedy(prob) is None


def test_dp_zero_lambda_is_min_op_energy_with_transitions():
    rng = np.random.default_rng(5)
    prob = random_problem(rng, n_layers=3, n_states=3)
    path = dp_best_path(prob, 0.0)
    r = prob.evaluate(path)
    # must be minimal in (e_op + e_trans) over all paths
    best = min(
        prob.evaluate(list(p))["e_op"] + prob.evaluate(list(p))["e_trans"]
        for p in itertools.product(*[range(3)] * 3))
    assert r["e_op"] + r["e_trans"] == pytest.approx(best, rel=1e-9)
