"""Capture golden compiler outputs for the pipeline-equivalence test.

Run ONCE against a known-good implementation (originally the
pre-refactor monolithic orchestrator) to freeze per-policy results:

    PYTHONPATH=src python tests/make_goldens.py

The staged pipeline must reproduce these `e_total` / `t_infer` / `path`
(layer voltage assignments) values to float tolerance — see
tests/test_pipeline_equivalence.py.
"""

from __future__ import annotations

import json
import pathlib
import time

from conftest import max_rate
from repro.core import OrchestratorConfig, POLICIES, compile_power_schedule
from repro.models.edge_cnn import edge_network

GOLDEN_PATH = pathlib.Path(__file__).parent / "golden" / "pipeline.json"

# (network, rate_fraction_of_max, n_max_rails) — small enough to run in
# CI, large enough to exercise the sweep, pruning, and refinement.
CASES = [
    ("squeezenet1.1", 0.90, 2),
    ("mobilenetv3-small", 0.85, 2),
    ("squeezenet1.1", 0.50, 3),
]


def main() -> None:
    out: dict[str, dict] = {}
    for network, frac, n_rails in CASES:
        rate = max_rate(network) * frac
        for policy in POLICIES:
            if policy == "ilp" and network != "squeezenet1.1":
                continue                      # keep CI runtime bounded
            key = f"{network}|{frac}|{n_rails}|{policy}"
            tic = time.perf_counter()
            s = compile_power_schedule(
                edge_network(network), rate,
                cfg=OrchestratorConfig(policy=policy, n_max_rails=n_rails),
                network=network)
            wall = time.perf_counter() - tic
            if s is None:
                out[key] = {"feasible": False}
            else:
                out[key] = {
                    "feasible": True,
                    "e_total": s.e_total,
                    "t_infer": s.t_infer,
                    "rails": list(s.rails),
                    "layer_voltages": [list(v) for v in s.layer_voltages],
                }
            print(f"{key}: {wall:.2f}s "
                  f"{'E=%.6g' % s.e_total if s else 'infeasible'}")
    GOLDEN_PATH.parent.mkdir(parents=True, exist_ok=True)
    GOLDEN_PATH.write_text(json.dumps(out, indent=1))
    print(f"wrote {GOLDEN_PATH} ({len(out)} cases)")


if __name__ == "__main__":
    main()
