"""Data pipeline, training loop, serving engine, power runtime, gradient
compression: the distributed-runtime substrate."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import OrchestratorConfig, compile_power_schedule
from repro.data.pipeline import DataConfig, SyntheticLMStream
from repro.hw.edge40nm import EDGE40NM_DEFAULT as ACC
from repro.models.edge_cnn import edge_network
from repro.models.transformer import Runtime, init_params
from repro.perfmodel import characterize_network, plan_banks
from repro.serve import (
    EngineConfig,
    PeriodicScheduler,
    PowerRuntime,
    ServingEngine,
)
from repro.train.grad_compress import ErrorFeedback, _quantize
from repro.train.optimizer import AdamWConfig, adamw_init, adamw_update, \
    lr_schedule, _zero_spec
from repro.train.trainer import TrainConfig, make_train_step

RT = Runtime()


# ------------------------------------------------------------- data

def test_data_stream_deterministic_and_seekable():
    cfg = DataConfig(vocab_size=1000, seq_len=32, global_batch=4, seed=3)
    s1, s2 = SyntheticLMStream(cfg), SyntheticLMStream(cfg)
    b_direct = s1.batch(17)
    it = s2.iterate(start_step=17)
    b_iter = next(it)
    np.testing.assert_array_equal(b_direct["tokens"], b_iter["tokens"])
    # labels are next-token shifted
    np.testing.assert_array_equal(b_direct["labels"][:, :-1],
                                  b_direct["tokens"][:, 1:])
    assert b_direct["tokens"].shape == (4, 32)
    assert b_direct["tokens"].max() < 1000


# ---------------------------------------------------------- optimizer

def test_adamw_decreases_quadratic_loss():
    ocfg = AdamWConfig(lr=0.1, warmup_steps=1, total_steps=100,
                       weight_decay=0.0, grad_clip=0.0)
    params = {"w": jnp.array([3.0, -2.0])}
    state, _ = adamw_init(params, {"w": None}, ocfg)
    for _ in range(60):
        grads = {"w": 2 * params["w"]}       # d/dw ||w||²
        params, state, _ = adamw_update(grads, state, params, ocfg)
    assert float(jnp.abs(params["w"]).max()) < 0.5


def test_lr_schedule_warmup_and_decay():
    ocfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100)
    assert float(lr_schedule(ocfg, jnp.array(5.0))) < 1.0
    peak = float(lr_schedule(ocfg, jnp.array(10.0)))
    end = float(lr_schedule(ocfg, jnp.array(100.0)))
    assert peak == pytest.approx(1.0, rel=0.01)
    assert end == pytest.approx(0.1, rel=0.05)


def test_zero_spec_shards_first_free_axis():
    from jax.sharding import PartitionSpec as P

    assert _zero_spec(P(None, "model"), (64, 32), 16) == \
        P("data", "model")
    assert _zero_spec(P("model", None), (64, 32), 16) == \
        P("model", "data")
    # axis not divisible → unchanged
    assert _zero_spec(P(None,), (7,), 16) == P(None)


def test_grad_accum_matches_full_batch():
    cfg = get_config("tinyllama-1.1b").reduced()
    params, specs = init_params(cfg, jax.random.PRNGKey(0))
    ocfg = AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=10)
    opt0, _ = adamw_init(params, specs, ocfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (8, 16), 0,
                              cfg.vocab_size)
    batch = {"tokens": toks, "labels": toks}
    step1 = make_train_step(cfg, TrainConfig(optimizer=ocfg), RT)
    step4 = make_train_step(cfg, TrainConfig(optimizer=ocfg,
                                             accum_steps=4), RT)
    p1, _, m1 = step1(params, opt0, batch)
    p4, _, m4 = step4(params, opt0, batch)
    assert m1["loss"] == pytest.approx(float(m4["loss"]), rel=5e-3)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p4)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=2e-2, atol=2e-3)


def test_training_reduces_loss_tiny_lm():
    cfg = get_config("tinyllama-1.1b").reduced()
    params, specs = init_params(cfg, jax.random.PRNGKey(0))
    ocfg = AdamWConfig(lr=3e-3, warmup_steps=3, total_steps=30)
    opt, _ = adamw_init(params, specs, ocfg)
    stream = SyntheticLMStream(DataConfig(
        vocab_size=cfg.vocab_size, seq_len=32, global_batch=8))
    step = jax.jit(make_train_step(cfg, TrainConfig(optimizer=ocfg), RT))
    losses = []
    for i in range(25):
        b = {k: jnp.asarray(v) for k, v in stream.batch(i).items()}
        params, opt, m = step(params, opt, b)
        losses.append(float(m["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.5


# ----------------------------------------------------- grad compression

def test_int8_quantize_error_bound():
    g = jax.random.normal(jax.random.PRNGKey(0), (128,)) * 5
    q, scale = _quantize(g)
    deq = q.astype(jnp.float32) * scale
    assert float(jnp.max(jnp.abs(deq - g))) <= float(scale) / 2 + 1e-6


def test_error_feedback_preserves_gradient_sum():
    """EF compression: cumulative compressed updates track cumulative
    true gradients (bias does not accumulate)."""
    params = {"w": jnp.zeros((64,))}
    ef = ErrorFeedback(params)
    rng = jax.random.PRNGKey(1)
    total_true = jnp.zeros((64,))
    total_comp = jnp.zeros((64,))
    for i in range(20):
        rng, k = jax.random.split(rng)
        g = {"w": jax.random.normal(k, (64,)) * 0.3}
        comp, ef = ef.compress(g)
        total_true += g["w"]
        total_comp += comp["w"]
    # residual bound: final difference ≤ one quantization step
    resid = float(jnp.max(jnp.abs(total_true - total_comp)))
    assert resid < 0.05


# ------------------------------------------------------------- serving

def test_engine_serves_all_requests():
    cfg = get_config("tinyllama-1.1b").reduced()
    params, _ = init_params(cfg, jax.random.PRNGKey(0))
    eng = ServingEngine(cfg, params, EngineConfig(
        max_batch=3, cache_len=64, max_new_tokens=6, eos_token=-1))
    rng = np.random.default_rng(0)
    rids = [eng.submit(list(rng.integers(1, cfg.vocab_size, 5)))
            for _ in range(7)]
    done = eng.run_to_completion()
    assert sorted(r.rid for r in done) == sorted(rids)
    assert all(len(r.generated) == 6 for r in done)


def test_engine_greedy_deterministic():
    cfg = get_config("tinyllama-1.1b").reduced()
    params, _ = init_params(cfg, jax.random.PRNGKey(0))
    outs = []
    for _ in range(2):
        eng = ServingEngine(cfg, params, EngineConfig(
            max_batch=2, cache_len=64, max_new_tokens=5, eos_token=-1))
        eng.submit([5, 6, 7])
        eng.submit([9, 10, 11, 12])
        done = eng.run_to_completion()
        outs.append({r.rid: tuple(r.generated) for r in done})
    assert outs[0] == outs[1]


def test_engine_mid_batch_slot_refill():
    """A finished sequence frees its slot and the next queued request
    is prefilled into it while the other slot's sequence keeps its KV
    state — per-slot continuous batching, not drain-then-refill."""
    cfg = get_config("tinyllama-1.1b").reduced()
    params, _ = init_params(cfg, jax.random.PRNGKey(0))

    def run_once():
        eng = ServingEngine(cfg, params, EngineConfig(
            max_batch=2, cache_len=64, max_new_tokens=6, eos_token=-1))
        eng.submit([5, 6, 7])            # slot 0
        eng.submit([9, 10, 11, 12])      # slot 1
        waiting = eng.submit([21, 22])   # queued
        eng.step()
        # slot 0's sequence hits its stop condition early
        eng.active[0].done = True
        eng.step()
        # the queued request took slot 0 mid-batch; slot 1 uninterrupted
        assert eng.active[0].rid == waiting
        assert eng.active[1].rid == 1 and not eng.active[1].done
        assert len(eng.completed) == 1 and eng.completed[0].rid == 0
        done = eng.run_to_completion()
        return {r.rid: tuple(r.generated) for r in done}

    first, second = run_once(), run_once()
    assert sorted(first) == [0, 1, 2]
    assert len(first[1]) == 6 and len(first[2]) == 6
    assert first == second                # refill path is deterministic


def test_engine_max_steps_returns_in_flight_truncated():
    """Exhausting max_steps must not lose in-flight requests: they come
    back flagged truncated with their partial generations; never-started
    requests stay queued."""
    cfg = get_config("tinyllama-1.1b").reduced()
    params, _ = init_params(cfg, jax.random.PRNGKey(0))
    eng = ServingEngine(cfg, params, EngineConfig(
        max_batch=2, cache_len=64, max_new_tokens=50, eos_token=-1))
    rids = [eng.submit([3 + i, 4 + i]) for i in range(3)]
    done = eng.run_to_completion(max_steps=2)
    assert sorted(r.rid for r in done) == rids[:2]
    assert all(r.truncated and 0 < len(r.generated) < 50 for r in done)
    assert [r.rid for r in eng.queue] == [rids[2]]
    assert eng.active == {} and eng.state is None
    # the engine remains serviceable after a truncation pass
    finished = eng.run_to_completion()
    assert [r.rid for r in finished] == [rids[2]]
    assert not finished[0].truncated
    assert len(finished[0].generated) == 50


# --------------------------------------------------- power runtime

def test_power_runtime_matches_compiler_prediction():
    """Executed interval energy == compiled schedule energy (the static
    schedule IS the deployment semantics)."""
    specs = edge_network("squeezenet1.1")
    costs = characterize_network(specs, ACC)
    plan = plan_banks(costs, ACC)
    for policy in ("baseline", "gating", "greedy_gating", "pfdnn_even"):
        sched = compile_power_schedule(
            specs, 40.0, cfg=OrchestratorConfig(policy=policy),
            network="sqz")
        assert sched is not None, policy
        led = PowerRuntime(sched, costs, plan, ACC).execute_interval()
        assert led.met_deadline
        assert led.e_total == pytest.approx(sched.e_total, rel=1e-6), \
            policy


def test_periodic_scheduler_accounting():
    specs = edge_network("mobilenetv3-small")
    costs = characterize_network(specs, ACC)
    plan = plan_banks(costs, ACC)
    sched = compile_power_schedule(
        specs, 60.0, cfg=OrchestratorConfig(policy="greedy_gating"),
        network="mnv3")
    run = PeriodicScheduler(
        PowerRuntime(sched, costs, plan, ACC), 60.0).run(5)
    assert run["deadline_misses"] == 0
    assert run["total_energy_j"] == pytest.approx(
        5 * sched.e_total, rel=1e-6)
