"""Per-architecture smoke tests (reduced same-family configs) +
decode-vs-forward consistency + MoE dense path correctness."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import moe as moe_lib
from repro.models.transformer import (
    Runtime,
    decode_step,
    forward_train,
    init_params,
    lm_head,
    prefill,
)

RT = Runtime()
KEY = jax.random.PRNGKey(0)
B, S, CACHE = 2, 16, 24


def _batch(cfg):
    tokens = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": tokens, "labels": tokens}
    if cfg.family == "audio":
        batch["encoder_frames"] = jax.random.normal(
            KEY, (B, cfg.encoder_seq, cfg.d_model))
    if cfg.family == "vlm":
        pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
        batch["positions"] = jnp.broadcast_to(pos[None], (3, B, S))
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_smoke_train_and_decode(arch):
    """One forward/train step + prefill + decode on CPU: output shapes
    correct, no NaNs (the assignment's per-arch smoke contract)."""
    cfg = get_config(arch).reduced()
    params, specs = init_params(cfg, KEY)
    assert jax.tree.structure(params) == jax.tree.structure(specs)
    batch = _batch(cfg)
    loss = forward_train(params, cfg, batch, RT)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), f"{arch}: non-finite loss"
    logits, state = prefill(params, cfg, batch, RT, cache_len=CACHE)
    assert logits.shape == (B, cfg.padded_vocab)
    assert bool(jnp.isfinite(logits).all())
    nxt = jnp.argmax(logits, -1).astype(jnp.int32)
    logits2, state2 = decode_step(params, cfg, state, nxt, RT)
    assert logits2.shape == (B, cfg.padded_vocab)
    assert bool(jnp.isfinite(logits2).all()), f"{arch}: NaN in decode"
    assert int(state2["lengths"][0]) == S + 1


@pytest.mark.parametrize("arch", ["tinyllama-1.1b", "qwen2-7b",
                                  "deepseek-v2-lite-16b", "xlstm-350m",
                                  "hymba-1.5b"])
def test_decode_matches_full_forward(arch):
    """prefill(S tokens) + decode(token S) must equal the full-sequence
    forward over S+1 tokens at the last position (cache correctness)."""
    cfg = get_config(arch).reduced()
    params, _ = init_params(cfg, KEY)
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S + 1), 0,
                              cfg.vocab_size)
    # full forward over S+1: last-position logits via prefill(S+1)
    full_logits, _ = prefill(params, cfg, {"tokens": toks}, RT,
                             cache_len=CACHE)
    # prefill S, then decode token S
    _, state = prefill(params, cfg, {"tokens": toks[:, :S]}, RT,
                       cache_len=CACHE)
    dec_logits, _ = decode_step(params, cfg, state, toks[:, S], RT)
    np.testing.assert_allclose(np.asarray(dec_logits),
                               np.asarray(full_logits),
                               rtol=2e-3, atol=2e-3)


def test_moe_dense_matches_manual():
    """Dense-MoE oracle agrees with an explicit per-token loop."""
    dims = moe_lib.MoeDims(n_experts=4, top_k=2, d_model=8, d_ff=16,
                           capacity_factor=10.0)
    k = jax.random.split(KEY, 5)
    t = 6
    x = jax.random.normal(k[0], (t, 8))
    wr = jax.random.normal(k[1], (8, 4)) * 0.1
    w1 = jax.random.normal(k[2], (4, 8, 16))
    w3 = jax.random.normal(k[3], (4, 8, 16))
    w2 = jax.random.normal(k[4], (4, 16, 8))
    out = moe_lib.moe_ffn_dense(x, wr, w1, w3, w2, dims)
    idx, cw = moe_lib.router_topk(x, wr, dims)
    expected = np.zeros((t, 8), np.float32)
    for ti in range(t):
        for j in range(2):
            e = int(idx[ti, j])
            h = (jax.nn.silu(x[ti] @ w1[e]) * (x[ti] @ w3[e]))
            expected[ti] += float(cw[ti, j]) * np.asarray(h @ w2[e])
    np.testing.assert_allclose(np.asarray(out), expected, rtol=2e-4,
                               atol=2e-4)


def test_vlm_mrope_text_grid_matches_plain_positions():
    """For text-only streams (equal grids) M-RoPE == standard RoPE, so
    supplying positions vs not must give identical losses."""
    cfg = get_config("qwen2-vl-72b").reduced()
    params, _ = init_params(cfg, KEY)
    tokens = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    batch1 = {"tokens": tokens, "labels": tokens,
              "positions": jnp.broadcast_to(pos[None], (3, B, S))}
    batch2 = {"tokens": tokens, "labels": tokens}
    l1 = forward_train(params, cfg, batch1, RT)
    l2 = forward_train(params, cfg, batch2, RT)
    assert jnp.allclose(l1, l2, rtol=1e-6)


def test_param_counts_match_analytic():
    """module param_count vs ModelConfig.n_params on full configs."""
    from repro.models.module import param_count
    from repro.models.transformer import abstract

    for arch in ("tinyllama-1.1b", "qwen2-7b", "phi3-mini-3.8b"):
        cfg = get_config(arch)
        sds, _ = abstract(cfg)
        actual = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(sds))
        expect = cfg.n_params()
        # analytic formula ignores norms/biases (< 0.2%)
        assert abs(actual - expect) / expect < 5e-3, arch


def test_edge_networks_layer_counts():
    from repro.models.edge_cnn import edge_network

    assert len(edge_network("squeezenet1.1")) == 26
    assert len(edge_network("resnet18")) == 20
    assert len(edge_network("mobilenetv3-small")) == 54
    assert len(edge_network("mobilevit-xxs")) == 70
