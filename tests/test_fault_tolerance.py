"""Checkpoint/restart + fault-tolerance drills.

The contract (train/elastic.py): a crashed-and-restarted run must produce
exactly the same training trajectory as an uninterrupted one — same
losses, same final parameters — because checkpoints are atomic and the
data stream is stateless/seekable."""

import dataclasses
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.data.pipeline import DataConfig
from repro.train.checkpoint import (
    AsyncCheckpointer,
    CheckpointCorruption,
    latest_step,
    prune_old,
    restore_checkpoint,
    save_checkpoint,
)
from repro.train.elastic import CrashRequested, ElasticRun, run_elastic
from repro.train.optimizer import AdamWConfig
from repro.train.trainer import TrainConfig


def _make_run(tmp_path: pathlib.Path) -> ElasticRun:
    cfg = get_config("tinyllama-1.1b").reduced()
    return ElasticRun(
        cfg=cfg,
        tcfg=TrainConfig(optimizer=AdamWConfig(
            lr=1e-3, warmup_steps=2, total_steps=12)),
        data=DataConfig(vocab_size=cfg.vocab_size, seq_len=16,
                        global_batch=4),
        ckpt_dir=tmp_path / "ckpt",
        ckpt_every=3,
    )


def test_checkpoint_roundtrip_bitwise(tmp_path):
    tree = {"a": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
            "b": {"c": jnp.ones((5,), jnp.bfloat16)}}
    save_checkpoint(tmp_path, 7, tree, meta={"next_step": 8})
    assert latest_step(tmp_path) == 7
    restored, meta = restore_checkpoint(tmp_path, 7, tree)
    assert meta["next_step"] == 8
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert a.dtype == b.dtype


def test_checkpoint_corruption_detected(tmp_path):
    tree = {"w": jnp.ones((4, 4))}
    path = save_checkpoint(tmp_path, 1, tree)
    # flip bytes in the arrays file
    f = path / "arrays.npz"
    raw = bytearray(f.read_bytes())
    raw[len(raw) // 2] ^= 0xFF
    f.write_bytes(bytes(raw))
    with pytest.raises((CheckpointCorruption, Exception)):
        restore_checkpoint(tmp_path, 1, tree)


def test_torn_checkpoint_ignored(tmp_path):
    tree = {"w": jnp.ones((2,))}
    save_checkpoint(tmp_path, 5, tree)
    # simulate a torn save: directory without COMMITTED
    torn = tmp_path / "step_00000009"
    torn.mkdir()
    (torn / "manifest.json").write_text("{}")
    assert latest_step(tmp_path) == 5


def test_prune_old_keeps_latest(tmp_path):
    tree = {"w": jnp.ones((2,))}
    for s in (1, 2, 3, 4):
        save_checkpoint(tmp_path, s, tree)
    prune_old(tmp_path, keep=2)
    assert latest_step(tmp_path) == 4
    assert not (tmp_path / "step_00000001").exists()


def test_crash_restart_reproduces_uninterrupted_run(tmp_path):
    """THE fault-tolerance drill: crash at step 7, restart, and compare
    the full trajectory + final params against a clean run."""
    run_a = _make_run(tmp_path / "a")
    clean = run_elastic(run_a, total_steps=12)

    run_b = _make_run(tmp_path / "b")
    with pytest.raises(CrashRequested):
        run_elastic(run_b, total_steps=12, crash_at=7)
    resumed = run_elastic(run_b, total_steps=12)      # restart
    assert resumed["resumed_from"] == 7  # ckpt at step 6 → next_step 7

    clean_losses = {h["step"]: h["loss"] for h in clean["history"]}
    for h in resumed["history"]:
        assert clean_losses[h["step"]] == pytest.approx(
            h["loss"], rel=1e-5), f"diverged at step {h['step']}"
    for a, b in zip(jax.tree.leaves(clean["params"]),
                    jax.tree.leaves(resumed["params"])):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=1e-5, atol=1e-6)


@pytest.mark.skipif(
    not hasattr(jax.sharding, "AxisType"),
    reason="installed jax lacks jax.sharding.AxisType "
           "(needs a newer jax than this environment ships)")
def test_elastic_restore_onto_different_sharding(tmp_path):
    """Restore re-places arrays under new shardings (mesh change)."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    tree = {"w": jnp.arange(16, dtype=jnp.float32).reshape(4, 4)}
    save_checkpoint(tmp_path, 1, tree)
    mesh = jax.make_mesh((1,), ("data",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    shardings = {"w": NamedSharding(mesh, P("data", None))}
    restored, _ = restore_checkpoint(tmp_path, 1, tree,
                                     shardings=shardings)
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.asarray(tree["w"]))
    assert restored["w"].sharding == shardings["w"]


def test_async_checkpointer_overlaps_and_commits(tmp_path):
    ckpt = AsyncCheckpointer(tmp_path, every_steps=2, keep=2)
    tree = {"w": jnp.ones((8,))}
    for step in range(6):
        ckpt.maybe_save(step, tree, meta={"next_step": step + 1})
    ckpt.wait()
    assert latest_step(tmp_path) == 4
    assert ckpt.saved_steps == [0, 2, 4]
