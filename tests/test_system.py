"""End-to-end behaviour of the paper's system: policy orderings, paper
claims (§6), and the TPU adaptation."""

import numpy as np
import pytest

from conftest import max_rate as _max_rate
from repro.core import (
    IlpBlowupError,
    OrchestratorConfig,
    compile_power_schedule,
    refine_candidates,
    solve_ilp,
    solve_lambda_dp,
)
from repro.core.tpu_adapter import (
    build_tpu_problem,
    layer_costs_from_dryrun,
)
from repro.hw.edge40nm import EDGE40NM_DEFAULT as ACC
from repro.models.edge_cnn import EDGE_NETWORKS, edge_network
from repro.perfmodel import characterize_network, plan_banks


def _energy(name: str, rate: float, policy: str) -> float | None:
    s = compile_power_schedule(
        edge_network(name), rate,
        cfg=OrchestratorConfig(policy=policy), network=name)
    return None if s is None else s.e_total


def test_policy_ordering_at_tight_deadline():
    """PF-DNN ≤ greedy+gating ≤ gating ≤ baseline (§6.1)."""
    rate = _max_rate("squeezenet1.1") * 0.92
    e = {p: _energy("squeezenet1.1", rate, p)
         for p in ("baseline", "gating", "greedy_gating", "pfdnn")}
    assert all(v is not None for v in e.values())
    assert e["pfdnn"] <= e["greedy_gating"] * (1 + 1e-9)
    assert e["greedy_gating"] <= e["gating"] * (1 + 1e-9)
    assert e["gating"] <= e["baseline"] * (1 + 1e-9)


def test_paper_claim_savings_band_at_max_rate():
    """§6.2: 34–48% energy reduction vs the aggressive baseline at each
    model's maximum feasible rate (calibration-robust bounds: ≥20% on
    every network, ≥34% on at least one)."""
    savings = []
    for name in EDGE_NETWORKS:
        rate = _max_rate(name) * 0.95
        eb = _energy(name, rate, "baseline")
        ep = _energy(name, rate, "pfdnn")
        if eb is None or ep is None:
            continue
        savings.append(1 - ep / eb)
    assert len(savings) >= 3
    assert min(savings) > 0.20, savings
    assert max(savings) > 0.34, savings


def test_paper_claim_convergence_at_relaxed_deadline():
    """§6.2: under relaxed deadlines pfdnn ≈ greedy+gating (≤2%)."""
    for name in ("squeezenet1.1", "resnet18"):
        rate = _max_rate(name) * 0.25
        eg = _energy(name, rate, "greedy_gating")
        ep = _energy(name, rate, "pfdnn")
        assert ep <= eg * (1 + 1e-9)
        assert eg / ep - 1 < 0.02, (name, eg, ep)


def test_paper_claim_rail_count_monotone():
    """§6.3: more rails never hurt; optimized ≤ evenly spaced."""
    specs = edge_network("mobilenetv3-small")
    rate = _max_rate("mobilenetv3-small") * 0.9
    energies = []
    for n in (1, 2, 3):
        s = compile_power_schedule(
            specs, rate,
            cfg=OrchestratorConfig(policy="pfdnn", n_max_rails=n),
            network="mnv3")
        assert s is not None
        energies.append(s.e_total)
    assert energies[1] <= energies[0] * (1 + 1e-9)
    assert energies[2] <= energies[1] * (1 + 1e-9)
    even = compile_power_schedule(
        specs, rate,
        cfg=OrchestratorConfig(policy="pfdnn_even", n_max_rails=3),
        network="mnv3")
    assert energies[2] <= even.e_total * (1 + 1e-9)


def test_paper_claim_transition_suppression():
    """§6.4: raising E_trans by orders of magnitude suppresses rail
    switches (up to 97% fewer in the paper)."""
    specs = edge_network("mobilenetv3-small")
    rate = _max_rate("mobilenetv3-small") * 0.9
    sw = {}
    for e_tr in (0.1e-9, 1e-6):
        s = compile_power_schedule(
            specs, rate,
            cfg=OrchestratorConfig(policy="pfdnn", e_switch_nom=e_tr),
            network="mnv3")
        assert s is not None
        sw[e_tr] = s.n_rail_switches
    assert sw[1e-6] <= sw[0.1e-9]
    if sw[0.1e-9] >= 5:
        assert sw[1e-6] <= 0.5 * sw[0.1e-9]


def test_gating_removes_most_memory_leakage():
    """§6.4: fine-grained memory gating reduces leakage by up to 90% —
    the awake-bank integral drops accordingly."""
    specs = edge_network("resnet18")     # most banks (176)
    costs = characterize_network(specs, ACC)
    plan = plan_banks(costs, ACC)
    awake_gated = sum(plan.awake_banks(i, True)
                      for i in range(len(costs)))
    awake_always = sum(plan.awake_banks(i, False)
                       for i in range(len(costs)))
    assert awake_gated < 0.15 * awake_always


def test_ilp_blowup_guard():
    """§6.5: the ILP instantiates Σ|S_i||S_{i+1}| transition variables
    and is refused past the memory budget (the paper's ILP-OOM regime)."""
    specs = edge_network("mobilevit-xxs")
    costs = characterize_network(specs, ACC)
    plan = plan_banks(costs, ACC)
    from repro.core import build_edge_problem

    prob = build_edge_problem(costs, plan, ACC,
                              tuple(np.linspace(0.9, 1.3, 9)), 0.05)
    with pytest.raises(IlpBlowupError):
        solve_ilp(prob, max_variables=100_000)


def test_schedule_artifact_roundtrip():
    s = compile_power_schedule(
        edge_network("squeezenet1.1"), 40.0,
        cfg=OrchestratorConfig(policy="pfdnn_even"), network="sqz")
    from repro.core import PowerSchedule

    s2 = PowerSchedule.from_json(s.to_json())
    assert s2.e_total == s.e_total
    assert s2.layer_voltages == s.layer_voltages
    prog = s2.program()
    assert prog[-1]["domain"] == "chip"
    assert any(op["op"] == "set_rail" for op in prog)


def test_tpu_adapter_end_to_end():
    """PF-DNN over TPU roofline terms: solves, meets the deadline, and
    beats the all-max-rail static assignment (beyond-paper adaptation)."""
    fake_record = {
        "cost": {"flops_per_device": 40e12, "bytes_per_device": 80e9,
                 "collective_bytes_per_device": 5e9}}
    layers = layer_costs_from_dryrun(fake_record, n_layers=24,
                                     gateable_fraction=0.9)
    rails = (0.7, 0.85, 1.0)
    t_deadline = 40e12 / 197e12 * 3.0     # 3× the compute floor
    prob = build_tpu_problem(layers, rails, t_deadline)
    best, cands, _ = solve_lambda_dp(prob)
    assert best is not None and best["feasible"]
    refined, _ = refine_candidates(prob, cands)
    static = prob.evaluate([
        next(i for i, s in enumerate(states)
             if s.voltages == (1.0, 1.0, 1.0))
        for states in prob.layer_states])
    assert refined["e_total"] < static["e_total"]
