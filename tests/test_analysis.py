"""Tests for the verification suite (``repro.analysis``): the
independent schedule certifier + its mutation-detection fixture, the
artifact-store audit walker, the PowerSchedule schema gate, the
determinism linter, and the lock-order analyzer."""

import dataclasses
import json
import pathlib
import textwrap
import threading

import pytest

from conftest import max_rate
from repro.analysis import lockcheck
from repro.analysis.certify import (
    DEADLINE_VIOLATED,
    ENERGY_MISMATCH,
    ILLEGAL_TRANSITION,
    LEDGER_DRIFT,
    RAIL_COUNT_EXCEEDED,
    certify,
    certify_store,
)
from repro.analysis.lint_determinism import (
    apply_baseline,
    lint_source,
    lint_tree,
    load_baseline,
    save_baseline,
)
from repro.core import OrchestratorConfig, compile_power_schedule
from repro.core.schedule import PowerSchedule, SCHEDULE_SCHEMA
from repro.hw.dvfs import V_GATED
from repro.hw.edge40nm import EDGE40NM_DEFAULT as ACC
from repro.models.edge_cnn import edge_network
from repro.perfmodel import characterize_network
from repro.service.store import ArtifactStore

NETWORK = "squeezenet1.1"
N_RAILS = 3


@pytest.fixture(scope="module")
def specs():
    return edge_network(NETWORK)


@pytest.fixture(scope="module")
def golden_sched(specs):
    """One representative compiled artifact (the full 23-case × 3-backend
    sweep is the CI ``analysis`` job, not a unit test)."""
    sched = compile_power_schedule(
        specs, max_rate(NETWORK) * 0.85,
        cfg=OrchestratorConfig(policy="pfdnn", n_max_rails=N_RAILS),
        network=NETWORK)
    assert sched is not None and sched.feasible
    return sched


# ===================================================== certifier: clean

@pytest.mark.parametrize("policy", ["baseline", "greedy_gating", "pfdnn"])
def test_certify_clean_policies(specs, policy):
    sched = compile_power_schedule(
        specs, max_rate(NETWORK) * 0.85,
        cfg=OrchestratorConfig(policy=policy, n_max_rails=N_RAILS),
        network=NETWORK)
    assert sched is not None
    cert = certify(sched, specs, acc=ACC, n_max_rails=N_RAILS)
    assert cert.ok, cert.summary()
    assert cert.violations == []
    # re-derivation agrees with the ledger to tolerance
    assert cert.derived["e_total"] == pytest.approx(sched.e_total,
                                                   rel=1e-9)
    assert cert.derived["t_infer"] == pytest.approx(sched.t_infer,
                                                   rel=1e-9)


def test_certify_dual_bound(golden_sched, specs):
    cert = certify(golden_sched, specs, acc=ACC, n_max_rails=N_RAILS)
    assert cert.dual is not None
    # weak duality: the bound never exceeds the recorded energy
    assert cert.dual.gap_abs >= -1e-9 * cert.dual.energy
    assert cert.dual.bound <= cert.dual.energy + 1e-12
    assert 0.0 <= cert.dual.gap_rel < 0.25   # pfdnn sits near the envelope


def test_certify_no_dual_skips(golden_sched, specs):
    cert = certify(golden_sched, specs, acc=ACC, dual=False)
    assert cert.ok and cert.dual is None


def test_certificate_round_trips(golden_sched, specs):
    cert = certify(golden_sched, specs, acc=ACC, n_max_rails=N_RAILS)
    d = cert.to_dict()
    assert d["ok"] and d["network"] == NETWORK
    json.dumps(d)            # serializable as-is
    assert "PASS" in cert.summary()


# ================================================= certifier: mutations

def _weighted_layer(specs):
    costs = characterize_network(specs, ACC)
    for i, c in enumerate(costs):
        if c.weight_bytes != 0 or c.cycles[2] > 0:
            return i
    raise AssertionError("network has no weighted layer")


def _off_rail_level(sched):
    for v in ACC.levels():
        if v not in sched.rails:
            return v
    raise AssertionError("rail set covers the whole menu")


def _set_volt(sched, layer, domain, value):
    rows = [list(v) for v in sched.layer_voltages]
    rows[layer][domain] = value
    return dataclasses.replace(
        sched, layer_voltages=[tuple(r) for r in rows])


# seeded corruption -> the violation kind the certifier must emit
MUTATIONS = [
    ("shaved_deadline",
     lambda s, specs: dataclasses.replace(s, t_max=s.t_infer * 0.5),
     DEADLINE_VIOLATED),
    ("off_rail_voltage",
     lambda s, specs: _set_volt(s, 0, 0, _off_rail_level(s)),
     RAIL_COUNT_EXCEEDED),
    ("off_menu_voltage",
     lambda s, specs: _set_volt(s, 0, 0, 0.123),
     ILLEGAL_TRANSITION),
    ("gated_compute",
     lambda s, specs: _set_volt(s, 0, 0, V_GATED),
     ILLEGAL_TRANSITION),
    ("gated_rram_weighted_layer",
     lambda s, specs: _set_volt(s, _weighted_layer(specs), 2, V_GATED),
     ILLEGAL_TRANSITION),
    ("halved_e_trans",
     lambda s, specs: dataclasses.replace(s, e_trans=s.e_trans * 0.5),
     ENERGY_MISMATCH),
    ("inflated_e_op",
     lambda s, specs: dataclasses.replace(s, e_op=s.e_op * (1 + 1e-5)),
     ENERGY_MISMATCH),
    ("broken_e_total_sum",
     lambda s, specs: dataclasses.replace(
         s, e_total=s.e_total * (1 + 1e-5)),
     LEDGER_DRIFT),
    ("bumped_awake_banks",
     lambda s, specs: dataclasses.replace(
         s, awake_banks=[s.awake_banks[0] + 1] + list(s.awake_banks[1:])),
     LEDGER_DRIFT),
    ("bumped_rail_switches",
     lambda s, specs: dataclasses.replace(
         s, n_rail_switches=s.n_rail_switches + 1),
     LEDGER_DRIFT),
    ("flipped_idle_flag",
     lambda s, specs: dataclasses.replace(
         s, z_active_idle=1 - int(s.z_active_idle)),
     LEDGER_DRIFT),
    ("false_infeasibility_claim",
     lambda s, specs: dataclasses.replace(s, feasible=False),
     LEDGER_DRIFT),
]


@pytest.mark.parametrize("name,mutate,expected",
                         MUTATIONS, ids=[m[0] for m in MUTATIONS])
def test_mutation_is_flagged(golden_sched, specs, name, mutate, expected):
    mutant = mutate(golden_sched, specs)
    cert = certify(mutant, specs, acc=ACC, n_max_rails=N_RAILS)
    assert not cert.ok, f"{name}: corruption certified clean"
    kinds = {v.kind for v in cert.violations}
    assert expected in kinds, \
        f"{name}: expected {expected}, got {sorted(kinds)}"


def test_clean_schedule_has_no_false_positives(golden_sched, specs):
    """The mutation fixture is only meaningful if the unmutated artifact
    certifies clean under the exact same call."""
    cert = certify(golden_sched, specs, acc=ACC, n_max_rails=N_RAILS)
    assert cert.ok and not cert.violations


def test_certify_wrong_layer_count(golden_sched, specs):
    mutant = dataclasses.replace(
        golden_sched,
        layer_voltages=golden_sched.layer_voltages[:-1],
        awake_banks=golden_sched.awake_banks[:-1])
    cert = certify(mutant, specs, acc=ACC)
    assert not cert.ok
    assert cert.violations[0].kind == LEDGER_DRIFT
    assert "layers" in cert.violations[0].where


def test_certify_calibrated_artifact_needs_cost_model(golden_sched, specs):
    mutant = dataclasses.replace(golden_sched, cost_model="abc123")
    with pytest.raises(ValueError, match="cost_model"):
        certify(mutant, specs, acc=ACC)


# ====================================================== store audit

def test_certify_store_clean(tmp_path, golden_sched):
    store = ArtifactStore(disk_path=tmp_path / "tier")
    store.put_schedule(("content", "goal", "cfg"), golden_sched)
    store.put_schedule(("content2", "goal", "cfg"), None)  # sentinel
    audit = certify_store(store)
    assert audit["ok"], audit["problems"]
    # 2 memory entries + 2 disk entries
    assert audit["entries"] == 4


def test_certify_store_flags_key_content_mismatch(tmp_path, golden_sched):
    store = ArtifactStore(disk_path=tmp_path / "tier")
    store.put_schedule(("content", "goal", "cfg"), golden_sched)
    sched_dir = tmp_path / "tier" / "schedules"
    entry_path = next(sched_dir.glob("*.json"))
    ent = json.loads(entry_path.read_text())
    ent["key"] = ["tampered", "goal", "cfg"]
    entry_path.write_text(json.dumps(ent))
    audit = certify_store(tmp_path / "tier")    # path form
    assert not audit["ok"]
    assert any("key↔content mismatch" in p["detail"]
               for p in audit["problems"])


def test_certify_store_flags_ledger_drift(tmp_path, golden_sched):
    store = ArtifactStore(disk_path=tmp_path / "tier")
    broken = dataclasses.replace(golden_sched,
                                 e_total=golden_sched.e_total * 2)
    store.put_schedule(("content", "goal", "cfg"), broken)
    audit = certify_store(store)
    assert not audit["ok"]
    assert any("ledger drift" in p["detail"] for p in audit["problems"])


def test_certify_store_flags_unparseable_payload(tmp_path):
    root = tmp_path / "tier"
    store = ArtifactStore(disk_path=root)
    store.put_schedule(("content", "goal", "cfg"), None)
    entry_path = next((root / "schedules").glob("*.json"))
    ent = json.loads(entry_path.read_text())
    ent["payload"] = "{not json"
    entry_path.write_text(json.dumps(ent))
    audit = certify_store(root)
    assert not audit["ok"]
    assert any("does not parse" in p["detail"] for p in audit["problems"])


# ============================================= PowerSchedule schema gate

def test_schedule_json_round_trip_carries_schema(golden_sched):
    d = json.loads(golden_sched.to_json())
    assert d["schema"] == SCHEDULE_SCHEMA
    again = PowerSchedule.from_json(golden_sched.to_json())
    assert again == golden_sched


def test_schedule_legacy_payload_still_loads(golden_sched):
    d = json.loads(golden_sched.to_json())
    del d["schema"]                       # pre-schema snapshot
    again = PowerSchedule.from_json(json.dumps(d))
    assert again == golden_sched


def test_schedule_refuses_newer_schema(golden_sched):
    d = json.loads(golden_sched.to_json())
    d["schema"] = 99
    with pytest.raises(ValueError,
                       match="refusing to misread a newer layout"):
        PowerSchedule.from_json(json.dumps(d))


def test_schedule_rejects_unknown_field(golden_sched):
    d = json.loads(golden_sched.to_json())
    d["surprise"] = 1
    with pytest.raises(ValueError, match="unknown field"):
        PowerSchedule.from_json(json.dumps(d))


def test_schedule_rejects_missing_field(golden_sched):
    d = json.loads(golden_sched.to_json())
    del d["e_total"]
    with pytest.raises(ValueError, match="missing"):
        PowerSchedule.from_json(json.dumps(d))


def test_schedule_rejects_non_object():
    with pytest.raises(ValueError):
        PowerSchedule.from_json("[1, 2, 3]")


# ==================================================== determinism linter

def test_lint_unseeded_rng():
    src = textwrap.dedent("""\
        import numpy as np
        x = np.random.rand(3)
        rng = np.random.default_rng()
        ok = np.random.default_rng(0)
    """)
    rules = [f.rule for f in lint_source(src, "m.py")]
    assert rules == ["unseeded-rng", "unseeded-rng"]


def test_lint_wall_clock_and_alias():
    src = textwrap.dedent("""\
        import time as t
        from time import perf_counter
        a = t.time()
        b = perf_counter()
    """)
    findings = lint_source(src, "m.py")
    assert [f.rule for f in findings] == ["wall-clock", "wall-clock"]
    assert findings[0].line == 3


def test_lint_set_iteration_and_float_accum():
    src = textwrap.dedent("""\
        s = {1, 2, 3}
        out = [x for x in {1, 2}]
        for x in set(s) | {4}:
            pass
        tot = sum({0.1, 0.2})
        fine = sorted({1, 2})
        also_fine = {x for x in {1, 2}}
    """)
    rules = sorted(f.rule for f in lint_source(src, "m.py"))
    assert rules == ["float-accum", "set-iteration", "set-iteration"]


def test_lint_inline_suppression():
    src = "import time\nx = time.time()  # pfdnn: allow(wall-clock)\n"
    assert lint_source(src, "m.py") == []
    # wrong rule in the allow -> still flagged
    src2 = "import time\nx = time.time()  # pfdnn: allow(unseeded-rng)\n"
    assert len(lint_source(src2, "m.py")) == 1


def test_lint_baseline_round_trip(tmp_path):
    tree = tmp_path / "pkg"
    tree.mkdir()
    (tree / "a.py").write_text("import time\nx = time.time()\n")
    findings = lint_tree(tree)
    assert len(findings) == 1
    bl_path = tmp_path / "baseline.json"
    save_baseline(bl_path, findings)
    baseline = load_baseline(bl_path)
    new, suppressed = apply_baseline(lint_tree(tree), baseline)
    assert new == [] and len(suppressed) == 1
    # a fresh finding is NOT suppressed by the old baseline
    (tree / "a.py").write_text(
        "import time\nx = time.time()\ny = time.monotonic()\n")
    new, suppressed = apply_baseline(lint_tree(tree), baseline)
    assert len(new) == 1 and "monotonic" in new[0].message


def test_repo_lint_is_clean_under_committed_baseline():
    root = pathlib.Path(__file__).resolve().parents[1] / "src" / "repro"
    baseline = load_baseline(
        pathlib.Path(__file__).parent / "determinism_baseline.json")
    new, _ = apply_baseline(lint_tree(root), baseline)
    assert new == [], [str(f) for f in new]


# ====================================================== lock-order check

@pytest.fixture
def recording():
    was = lockcheck.enabled()
    lockcheck.enable()
    lockcheck.reset()
    yield
    lockcheck.reset()
    if not was:
        lockcheck.disable()


def test_make_lock_plain_when_disabled():
    if lockcheck.enabled():
        pytest.skip("suite running under PFDNN_LOCKCHECK=1")
    lock = lockcheck.make_lock("x._lock")
    assert isinstance(lock, type(threading.Lock()))


def test_nested_acquire_records_edge(recording):
    a = lockcheck.make_lock("a._lock")
    b = lockcheck.make_lock("b._lock")
    with a:
        with b:
            pass
    g = lockcheck.graph()
    assert g["edges"] == {"a._lock -> b._lock": 1}
    assert lockcheck.assert_clean()["ok"]


def test_opposite_orders_form_cycle(recording):
    a = lockcheck.make_lock("a._lock")
    b = lockcheck.make_lock("b._lock")
    with a:
        with b:
            pass
    with b:
        with a:
            pass
    report = lockcheck.check()
    assert report["cycles"] == [["a._lock", "b._lock"]]
    with pytest.raises(lockcheck.LockOrderError):
        lockcheck.assert_clean()


def test_reentrant_self_acquire_is_not_an_edge(recording):
    r = lockcheck.make_lock("r._lock", reentrant=True)
    with r:
        with r:
            pass
    assert lockcheck.graph()["edges"] == {}


def test_barrier_hazard(recording):
    a = lockcheck.make_lock("a._lock")
    lockcheck.barrier("clear")           # nothing held: fine
    with a:
        lockcheck.barrier("compile_many")
    report = lockcheck.check()
    assert report["hazards"] == [
        {"barrier": "compile_many", "held": ["a._lock"]}]
    assert not report["ok"]


def test_edges_recorded_across_threads(recording):
    a = lockcheck.make_lock("a._lock")
    b = lockcheck.make_lock("b._lock")

    def worker():
        with a:
            with b:
                pass

    ts = [threading.Thread(target=worker) for _ in range(4)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    assert lockcheck.graph()["edges"] == {"a._lock -> b._lock": 4}


def test_dump_and_merge(recording, tmp_path):
    a = lockcheck.make_lock("a._lock")
    b = lockcheck.make_lock("b._lock")
    with a:
        with b:
            pass
    path = tmp_path / "graph.jsonl"
    lockcheck.dump(path)
    lockcheck.dump(path)                 # second "process"
    merged = lockcheck.merge_dumps(path)
    assert merged["edges"] == {("a._lock", "b._lock"): 2}
    assert merged["locks"] == ["a._lock", "b._lock"]
    assert merged["hazards"] == []


def test_find_cycles_three_node():
    edges = [("a", "b"), ("b", "c"), ("c", "a"), ("c", "d")]
    assert lockcheck.find_cycles(edges) == [["a", "b", "c"]]
    assert lockcheck.find_cycles([("a", "b"), ("b", "c")]) == []


def test_static_nesting_scan(tmp_path):
    (tmp_path / "mod.py").write_text(textwrap.dedent("""\
        class C:
            def f(self):
                with self._lock:
                    with self.agg_lock:
                        pass

            def g(self):
                with self._lock:
                    def inner():
                        with self.agg_lock:   # new frame: not nested
                            pass
                    return inner
    """))
    nests = lockcheck.static_lock_nesting(tmp_path)
    assert [(n.outer, n.inner) for n in nests] == \
        [("mod._lock", "mod.agg_lock")]


def test_cross_check_coverage_and_cycles(tmp_path):
    (tmp_path / "m.py").write_text(textwrap.dedent("""\
        def f(self):
            with self._lock:
                with self.agg_lock:
                    pass
    """))
    nests = lockcheck.static_lock_nesting(tmp_path)
    covered = lockcheck.cross_check(
        nests, [("m._lock", "m.agg_lock")])
    assert covered["ok"] and covered["uncovered"] == []
    uncovered = lockcheck.cross_check(nests, [])
    assert uncovered["ok"]               # coverage gaps are non-fatal
    assert len(uncovered["uncovered"]) == 1
    # opposite textual orders are a static inversion: fatal
    (tmp_path / "n.py").write_text(textwrap.dedent("""\
        def g(self):
            with self.agg_lock:
                with self._lock:
                    pass
    """))
    both = lockcheck.static_lock_nesting(tmp_path)
    # alias the two modules' locks onto one namespace for the check
    renamed = [lockcheck.StaticNesting(
        n.outer.split(".", 1)[1], n.inner.split(".", 1)[1],
        n.path, n.line) for n in both]
    report = lockcheck.cross_check(renamed, [])
    assert not report["ok"] and report["static_cycles"]


def test_instrumented_lock_nonblocking_and_locked(recording):
    a = lockcheck.make_lock("a._lock")
    assert a.acquire(blocking=False)
    assert a.locked()
    assert not a.acquire(blocking=False)  # failed acquire: no record
    a.release()
    assert not a.locked()
    assert lockcheck.graph()["edges"] == {}


# ========================================================== CLI surface

def test_cli_lint_clean_and_exit_codes(tmp_path, capsys):
    from repro.analysis.__main__ import main
    tree = tmp_path / "pkg"
    tree.mkdir()
    (tree / "ok.py").write_text("x = 1\n")
    assert main(["lint", "--root", str(tree)]) == 0
    (tree / "bad.py").write_text("import time\nx = time.time()\n")
    assert main(["lint", "--root", str(tree)]) == 1
    assert main(["lint", "--root", str(tree), "--write-baseline"]) == 2
    bl = tmp_path / "bl.json"
    assert main(["lint", "--root", str(tree), "--baseline", str(bl),
                 "--write-baseline"]) == 0
    assert main(["lint", "--root", str(tree),
                 "--baseline", str(bl)]) == 0
    capsys.readouterr()


def test_cli_certify_schedule_file(tmp_path, golden_sched, capsys):
    from repro.analysis.__main__ import main
    path = tmp_path / "sched.json"
    path.write_text(golden_sched.to_json())
    assert main(["certify", str(path), "--n-max-rails", str(N_RAILS),
                 "--no-dual"]) == 0
    broken = dataclasses.replace(golden_sched,
                                 e_op=golden_sched.e_op * 2)
    path.write_text(broken.to_json())
    assert main(["certify", str(path), "--no-dual"]) == 1
    out = capsys.readouterr().out
    assert "FAIL" in out and ENERGY_MISMATCH in out


def test_cli_certify_nothing_to_do():
    from repro.analysis.__main__ import main
    assert main(["certify"]) == 2


def test_cli_lockcheck_on_dump(tmp_path, recording, capsys):
    from repro.analysis.__main__ import main
    a = lockcheck.make_lock("a._lock")
    b = lockcheck.make_lock("b._lock")
    with a:
        with b:
            pass
    dump_path = tmp_path / "g.jsonl"
    lockcheck.dump(dump_path)
    src_root = tmp_path / "src"
    src_root.mkdir()
    assert main(["lockcheck", "--dump", str(dump_path),
                 "--root", str(src_root)]) == 0
    # now a conflicting process dump creates a cycle
    lockcheck.reset()
    a2 = lockcheck.make_lock("a._lock")
    b2 = lockcheck.make_lock("b._lock")
    with b2:
        with a2:
            pass
    lockcheck.dump(dump_path)
    assert main(["lockcheck", "--dump", str(dump_path),
                 "--root", str(src_root)]) == 1
    capsys.readouterr()
