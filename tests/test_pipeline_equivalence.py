"""Staged-pipeline equivalence: the policy-registry + CompilationContext
+ batched-evaluator compiler must reproduce the monolithic pre-refactor
implementation exactly (golden outputs), and the vectorized evaluators
must agree with a straightforward scalar reference."""

import json
import pathlib

import numpy as np
import pytest

from conftest import max_rate, random_problem
from repro.core import (
    CompilationContext,
    OrchestratorConfig,
    POLICIES,
    build_edge_problem,
    compile_power_schedule,
    get_policy,
    register_policy,
)
from repro.core.problem import IdleModel, ScheduleProblem, StateCost
from repro.hw.dvfs import TransitionModel, V_GATED
from repro.hw.edge40nm import EDGE40NM_DEFAULT as ACC
from repro.models.edge_cnn import edge_network
from repro.perfmodel import characterize_network, plan_banks

GOLDEN = json.loads(
    (pathlib.Path(__file__).parent / "golden" / "pipeline.json")
    .read_text())


# ----------------------------------------------------- golden equivalence

@pytest.mark.parametrize("key", sorted(GOLDEN))
def test_pipeline_matches_pre_refactor_golden(key):
    """Every policy × config: e_total / t_infer / per-layer voltage path
    match the frozen pre-refactor outputs to float tolerance."""
    network, frac, n_rails, policy = key.split("|")
    golden = GOLDEN[key]
    rate = max_rate(network) * float(frac)
    s = compile_power_schedule(
        edge_network(network), rate,
        cfg=OrchestratorConfig(policy=policy, n_max_rails=int(n_rails)),
        network=network)
    if not golden["feasible"]:
        assert s is None
        return
    assert s is not None
    assert s.e_total == pytest.approx(golden["e_total"], rel=1e-9)
    assert s.t_infer == pytest.approx(golden["t_infer"], rel=1e-9)
    assert list(s.rails) == golden["rails"]
    assert [list(v) for v in s.layer_voltages] == golden["layer_voltages"]


def test_warm_start_does_not_change_the_schedule():
    """The warm-started, incumbent-cut sweep is an acceleration only."""
    rate = max_rate("squeezenet1.1") * 0.8
    specs = edge_network("squeezenet1.1")
    cold = compile_power_schedule(
        specs, rate, cfg=OrchestratorConfig(
            policy="pfdnn", n_max_rails=2, warm_start=False),
        network="sqz")
    warm = compile_power_schedule(
        specs, rate, cfg=OrchestratorConfig(
            policy="pfdnn", n_max_rails=2, warm_start=True),
        network="sqz")
    assert warm.rails == cold.rails
    assert warm.e_total == pytest.approx(cold.e_total, rel=1e-9)
    assert warm.layer_voltages == cold.layer_voltages


# --------------------------------------------- context slice invariant

def test_context_subset_view_matches_direct_build():
    """A rail subset sliced from the master table is elementwise
    identical to the problem the monolithic builder produces."""
    specs = edge_network("mobilenetv3-small")
    costs = characterize_network(specs, ACC)
    plan = plan_banks(costs, ACC)
    ctx = CompilationContext(specs, 40.0, acc=ACC, network="mnv3")
    for rails in [(0.9, 1.1, 1.3), (1.3,), (0.95, 1.2)]:
        view = ctx.problem_for(rails, gating=True, allow_sleep=True)
        direct = build_edge_problem(costs, plan, ACC, rails, 1.0 / 40.0)
        assert view.n_layers == direct.n_layers
        for i in range(direct.n_layers):
            assert view.layer_states[i] == direct.layer_states[i]
        for i in range(direct.n_layers - 1):
            np.testing.assert_array_equal(
                view.transition_arrays(i)[0],
                direct.transition_arrays(i)[0])
            np.testing.assert_array_equal(
                view.transition_arrays(i)[1],
                direct.transition_arrays(i)[1])


# ------------------------------------------------- batched evaluators

def _reference_evaluate(problem: ScheduleProblem, path) -> dict:
    """Straightforward scalar re-implementation (the pre-refactor loop,
    with the corrected rail-switch semantics)."""
    t = e = 0.0
    e_trans = t_trans = 0.0
    n_switches = 0
    for i, s in enumerate(path):
        t += problem._t_op[i][s]
        e += problem._e_op[i][s]
        if i + 1 < problem.n_layers:
            tt, et = problem.transition_arrays(i)
            t_trans += tt[s, path[i + 1]]
            e_trans += et[s, path[i + 1]]
            va = problem._volts[i][s]
            vb = problem._volts[i + 1][path[i + 1]]
            if any(a != b and a != V_GATED and b != V_GATED
                   for a, b in zip(va, vb)):
                n_switches += 1
    t_infer = t + t_trans
    slack = problem.t_max - t_infer
    e_idle = problem.idle.energy(slack)
    return {
        "t_infer": t_infer,
        "feasible": t_infer <= problem.t_max + 1e-15,
        "e_op": e, "e_trans": e_trans, "t_trans": t_trans,
        "e_idle": e_idle,
        "e_total": e + e_trans + e_idle,
        "z": problem.idle.z_choice(slack),
        "n_rail_switches": n_switches,
    }


@pytest.mark.parametrize("seed", range(8))
def test_evaluate_paths_matches_scalar_reference(seed):
    rng = np.random.default_rng(seed)
    prob = random_problem(rng, n_layers=6, n_states=5)
    paths = [[int(rng.integers(len(s))) for s in prob.layer_states]
             for _ in range(32)]
    batch = prob.evaluate_paths(paths)
    for j, path in enumerate(paths):
        ref = _reference_evaluate(prob, path)
        row = ScheduleProblem.result_row(batch, j)
        scalar = prob.evaluate(path)
        for key, want in ref.items():
            assert row[key] == pytest.approx(want, rel=1e-12), key
            assert scalar[key] == pytest.approx(want, rel=1e-12), key
        # the rail-switch count must agree exactly batch vs scalar
        assert row["n_rail_switches"] == scalar["n_rail_switches"] \
            == ref["n_rail_switches"]


def test_rail_switch_count_excludes_gating():
    """Power-gating entries/exits (V_GATED) are not rail switches."""
    mk = lambda v: StateCost(voltages=v, t_op=1e-4, e_op=1e-6)
    layers = [
        [mk((1.0, 1.0, 1.0))],
        [mk((1.0, 1.0, V_GATED))],   # gate RRAM: NOT a rail switch
        [mk((1.0, 1.0, 1.0))],       # wake RRAM: NOT a rail switch
        [mk((1.1, 1.0, 1.0))],       # compute rail change: IS one
        [mk((1.1, 1.0, 1.0))],       # no change
    ]
    prob = ScheduleProblem(
        layer_states=layers, t_max=1.0,
        idle=IdleModel(p_idle=1e-3),
        transition_model=TransitionModel())
    r = prob.evaluate([0, 0, 0, 0, 0])
    assert r["n_rail_switches"] == 1
    batch = prob.evaluate_paths([[0, 0, 0, 0, 0]])
    assert int(batch["n_rail_switches"][0]) == 1


def test_runtime_ledger_switch_count_matches_compiler():
    from repro.serve import PowerRuntime

    specs = edge_network("squeezenet1.1")
    costs = characterize_network(specs, ACC)
    plan = plan_banks(costs, ACC)
    for policy in ("gating", "greedy_gating", "pfdnn_even"):
        sched = compile_power_schedule(
            specs, 40.0, cfg=OrchestratorConfig(policy=policy),
            network="sqz")
        led = PowerRuntime(sched, costs, plan, ACC).execute_interval()
        assert led.n_rail_switches == sched.n_rail_switches, policy


def test_refine_with_zero_move_budget_is_identity():
    from repro.core import refine_path

    rng = np.random.default_rng(0)
    prob = random_problem(rng, n_layers=6, n_states=5)
    path = [int(rng.integers(len(s))) for s in prob.layer_states]
    result, moves = refine_path(prob, path, max_moves=0)
    assert moves == 0
    assert result["path"] == path


# ------------------------------------------------------ policy registry

def test_policy_registry_contents_and_errors():
    assert POLICIES == ("baseline", "gating", "greedy", "greedy_gating",
                        "pfdnn", "pfdnn_even", "pfdnn_nopp", "ilp")
    for name in POLICIES:
        assert callable(get_policy(name))
    with pytest.raises(ValueError, match="unknown policy"):
        get_policy("nope")
    with pytest.raises(ValueError, match="already registered"):
        register_policy("pfdnn")(lambda ctx, cfg: None)


def test_custom_policy_plugs_in_without_touching_the_driver():
    name = "test_vmax_everywhere"
    try:
        @register_policy(name)
        def solve_vmax(ctx, cfg):
            from repro.core.policies import emit_schedule

            problem = ctx.problem_for((ctx.acc.v_max,), gating=False,
                                      allow_sleep=False, via_master=False)
            result = problem.evaluate([0] * problem.n_layers)
            return emit_schedule(name, ctx, problem, result, {},
                                 gating=False)

        s = compile_power_schedule(
            edge_network("squeezenet1.1"), 40.0,
            cfg=OrchestratorConfig(policy=name), network="sqz")
        assert s is not None and s.policy == name
        ref = compile_power_schedule(
            edge_network("squeezenet1.1"), 40.0,
            cfg=OrchestratorConfig(policy="baseline"), network="sqz")
        assert s.e_total == pytest.approx(ref.e_total, rel=1e-12)
    finally:
        from repro.core import policies as _p

        _p._REGISTRY.pop(name, None)