"""Calibration subsystem: cost models, the characterization harness,
ledger-learned corrections, and input-adaptive policy tables."""

import dataclasses

import numpy as np
import pytest

from repro.calib import (
    CalibratedCostModel,
    HarnessConfig,
    ResidualEstimator,
    RooflineTable,
    SchedulePolicyTable,
    calibration_key,
    compile_policy_table,
    host_fingerprint,
    identity_model,
    model_from_residuals,
    run_harness,
    solver_kernel_walls,
    sparsity_cost_model,
    synthetic_measurement,
)
from repro.calib.policy_table import PolicyBand
from repro.core.goals import MinEnergy
from repro.core.schedule import PowerSchedule
from repro.hw.edge40nm import EDGE40NM_DEFAULT as ACC
from repro.perfmodel.gating import plan_banks
from repro.perfmodel.layer_costs import (
    characterize_network,
    conv_spec,
    fc_spec,
    pool_spec,
)
from repro.serve.control_plane import (
    AdaptiveConfig,
    AdaptiveScheduler,
    serve_trace,
)
from repro.serve.faults import FaultConfig, FaultInjector, linear_drift
from repro.service import ArtifactStore, CompileService

SPECS = [conv_spec("c1", 14, 14, 8, 16, 3),
         pool_spec("p1", 14, 14, 16, 2),
         fc_spec("f1", 784, 10)]
DEADLINE = 0.01


def _same_schedule(a: PowerSchedule, b: PowerSchedule) -> bool:
    return (a.rails == b.rails
            and a.layer_voltages == b.layer_voltages
            and a.awake_banks == b.awake_banks
            and a.e_total == b.e_total)


# ------------------------------------------------- CalibratedCostModel

class TestCalibratedCostModel:
    def test_validation(self):
        with pytest.raises(ValueError, match="layer"):
            CalibratedCostModel(scale=())
        with pytest.raises(ValueError, match="positive"):
            CalibratedCostModel(scale=(1.0, 0.0))
        with pytest.raises(ValueError, match="positive"):
            CalibratedCostModel(scale=(1.0, -0.5))
        with pytest.raises(ValueError, match="positive"):
            CalibratedCostModel(scale=(float("nan"),))

    def test_digest_depends_on_scale_and_source(self):
        a = CalibratedCostModel(scale=(1.1, 0.9))
        b = CalibratedCostModel(scale=(1.1, 0.9))
        c = CalibratedCostModel(scale=(1.1, 0.95))
        d = CalibratedCostModel(scale=(1.1, 0.9), source="harness")
        assert a.digest == b.digest
        assert a.digest != c.digest
        assert a.digest != d.digest

    def test_apply_scales_cycles_and_energy_together(self):
        costs = characterize_network(SPECS, ACC)
        model = CalibratedCostModel(scale=(2.0, 1.0, 0.5))
        out = model.apply(costs)
        for c0, c1, s in zip(costs, out, model.scale):
            assert c1.cycles == tuple(cy * s for cy in c0.cycles)
            assert c1.dyn_energy_nom == tuple(
                e * s for e in c0.dyn_energy_nom)
        # scale 1.0 layers are the same object, not a copy
        assert out[1] is costs[1]

    def test_apply_length_mismatch(self):
        costs = characterize_network(SPECS, ACC)
        with pytest.raises(ValueError, match="layers"):
            CalibratedCostModel(scale=(1.0, 1.0)).apply(costs)

    def test_max_deviation(self):
        m = CalibratedCostModel(scale=(1.2, 0.8))
        assert m.max_deviation() == pytest.approx(0.2)
        other = CalibratedCostModel(scale=(1.2, 1.0))
        assert m.max_deviation(other) == pytest.approx(0.2)

    def test_identity_model(self):
        m = identity_model(3)
        assert m.scale == (1.0, 1.0, 1.0)
        costs = characterize_network(SPECS, ACC)
        assert all(a is b for a, b in zip(m.apply(costs), costs))


# --------------------------------------------------- ResidualEstimator

def _ledger_like(t_ops):
    layer = dataclasses.make_dataclass("L", ["t_op"])
    led = dataclasses.make_dataclass("Led", ["layers"])
    return led(layers=[layer(t_op=float(t)) for t in t_ops])


class TestResidualEstimator:
    def test_validation(self):
        with pytest.raises(ValueError, match="n_layers"):
            ResidualEstimator(0)
        with pytest.raises(ValueError, match="min_samples"):
            ResidualEstimator(2, window=4, min_samples=8)

    def test_withholds_until_min_samples(self):
        est = ResidualEstimator(2, window=8, min_samples=3)
        pred = _ledger_like([1.0, 2.0])
        est.observe(_ledger_like([1.3, 2.6]), pred)
        est.observe(_ledger_like([1.3, 2.6]), pred)
        assert est.estimate() is None
        est.observe(_ledger_like([1.3, 2.6]), pred)
        np.testing.assert_allclose(est.estimate(), [1.3, 1.3])

    def test_median_rejects_noise(self, rng):
        est = ResidualEstimator(1, window=32, min_samples=16)
        pred = _ledger_like([1.0])
        for _ in range(32):
            noise = float(np.exp(rng.normal(0.0, 0.05)))
            est.observe(_ledger_like([1.25 * noise]), pred)
        assert est.estimate()[0] == pytest.approx(1.25, rel=0.05)

    def test_dead_layer_pinned_to_one(self):
        est = ResidualEstimator(2, window=4, min_samples=1)
        est.observe(_ledger_like([1.5, 0.0]), _ledger_like([1.0, 0.0]))
        np.testing.assert_allclose(est.estimate(), [1.5, 1.0])

    def test_shape_mismatch(self):
        est = ResidualEstimator(2, window=4, min_samples=1)
        with pytest.raises(ValueError, match="mismatch"):
            est.observe(_ledger_like([1.0]), _ledger_like([1.0, 2.0]))

    def test_clear(self):
        est = ResidualEstimator(1, window=4, min_samples=1)
        est.observe(_ledger_like([2.0]), _ledger_like([1.0]))
        assert est.n == 1
        est.clear()
        assert est.n == 0 and est.estimate() is None

    def test_model_from_residuals_clamps_and_quantizes(self):
        model = model_from_residuals(np.array([100.0, 0.001, 1.23456]))
        assert model.scale == (4.0, 0.25, 1.235)
        # near-equal estimates share one digest (store-fragmentation
        # guard)
        a = model_from_residuals(np.array([1.30001]))
        b = model_from_residuals(np.array([1.29999]))
        assert a.digest == b.digest


# ------------------------------------------------------------- harness

class TestHarness:
    def test_config_validation(self):
        with pytest.raises(ValueError, match="repeats"):
            HarnessConfig(repeats=0)
        with pytest.raises(ValueError, match="kinds"):
            HarnessConfig(kinds=("conv", "nosuch"))

    def test_parity_mode_all_ones(self):
        table = run_harness(ACC, HarnessConfig(repeats=1))
        for kind, (tr, er) in table.ratios_by_kind().items():
            assert tr == 1.0 and er == 1.0, kind
        model = table.cost_model(SPECS)
        assert model.scale == (1.0, 1.0, 1.0)

    def test_deterministic_with_noise(self):
        cfg = HarnessConfig(seed=7, repeats=3)
        meas = synthetic_measurement({"conv": 1.2}, noise_sigma=0.05)
        t1 = run_harness(ACC, cfg, measure=meas)
        t2 = run_harness(ACC, cfg, measure=meas)
        assert t1.to_record() == t2.to_record()

    def test_synthetic_truth_recovered(self):
        truth = {"conv": 1.3, "fc": 0.8}
        table = run_harness(
            ACC, HarnessConfig(repeats=5, seed=1),
            measure=synthetic_measurement(truth, noise_sigma=0.02))
        ratios = table.ratios_by_kind()
        assert ratios["conv"][0] == pytest.approx(1.3, rel=0.05)
        assert ratios["fc"][0] == pytest.approx(0.8, rel=0.05)
        assert ratios["pool"][0] == pytest.approx(1.0, rel=0.05)
        model = table.cost_model(SPECS)
        assert model.scale[0] == pytest.approx(1.3, abs=0.1)   # conv
        assert model.scale[1] == pytest.approx(1.0, abs=0.05)  # pool
        assert model.scale[2] == pytest.approx(0.8, abs=0.1)   # fc

    def test_record_round_trip(self):
        table = run_harness(ACC, HarnessConfig(repeats=1))
        back = RooflineTable.from_record(table.to_record())
        assert back.to_record() == table.to_record()
        assert back.key == table.key

    def test_key_sensitivity(self):
        host = host_fingerprint()
        base = calibration_key(ACC, HarnessConfig(), host)
        assert calibration_key(ACC, HarnessConfig(), host) == base
        assert calibration_key(ACC, HarnessConfig(seed=1), host) != base
        assert calibration_key(
            ACC, HarnessConfig(), {**host, "machine": "other"}) != base

    def test_store_publication_and_reuse(self, tmp_path):
        store = ArtifactStore(disk_path=tmp_path / "tier")
        cfg = HarnessConfig(repeats=1)
        t1 = run_harness(ACC, cfg, store=store)
        assert store.misses["calibration"] == 1
        t2 = run_harness(ACC, cfg, store=store)
        assert store.hits["calibration"] == 1
        assert t2.to_record() == t1.to_record()
        # a second store over the same disk tier (another process in
        # farm terms) reuses the published artifact
        store2 = ArtifactStore(disk_path=tmp_path / "tier")
        t3 = run_harness(ACC, cfg, store=store2)
        assert store2.disk_hits["calibration"] == 1
        assert t3.to_record() == t1.to_record()

    def test_solver_kernel_walls(self):
        w = solver_kernel_walls(repeats=1, n_layers=6, s_pad=8,
                                k_weights=4)
        assert w["wall_s_median"] > 0.0
        assert w["backend"]
        # the timed slab is a real solve: the checksum pins the paths
        w2 = solver_kernel_walls(repeats=1, n_layers=6, s_pad=8,
                                 k_weights=4)
        assert w2["checksum"] == w["checksum"]


# --------------------------------------------- cost-model compilation

class TestCalibratedCompile:
    def test_identity_model_bit_identical_to_static(self):
        with CompileService(ACC) as svc:
            static = svc.compile(SPECS, goal=MinEnergy(
                deadline_s=DEADLINE))
            ident = svc.compile(SPECS, goal=MinEnergy(
                deadline_s=DEADLINE), cost_model=identity_model(3))
        assert _same_schedule(static, ident)
        assert static.cost_model == "static"
        assert ident.cost_model == identity_model(3).digest

    def test_cache_namespaces_never_collide(self):
        with CompileService(ACC) as svc:
            static = svc.compile(SPECS, goal=MinEnergy(
                deadline_s=DEADLINE))
            model = sparsity_cost_model(0.5, SPECS)
            cal = svc.compile(SPECS, goal=MinEnergy(
                deadline_s=DEADLINE), cost_model=model)
            # two distinct cache entries; repeats hit their own
            assert svc.store.stats()["schedules"] == 2
            again = svc.compile(SPECS, goal=MinEnergy(
                deadline_s=DEADLINE), cost_model=model)
        assert _same_schedule(cal, again)
        assert cal.cost_model == model.digest != static.cost_model
        # the calibrated solve planned for less MAC work
        assert cal.e_total < static.e_total

    def test_schedule_json_round_trip_keeps_provenance(self):
        with CompileService(ACC) as svc:
            model = sparsity_cost_model(0.5, SPECS)
            sched = svc.compile(SPECS, goal=MinEnergy(
                deadline_s=DEADLINE), cost_model=model)
        back = PowerSchedule.from_json(sched.to_json())
        assert back.cost_model == model.digest
        # pre-calibration serialized schedules deserialize as static
        d = __import__("json").loads(sched.to_json())
        d.pop("cost_model")
        legacy = PowerSchedule.from_json(__import__("json").dumps(d))
        assert legacy.cost_model == "static"

    def test_reused_context_model_mismatch_raises(self):
        from repro.core import orchestrator

        with CompileService(ACC) as svc:
            model = sparsity_cost_model(0.5, SPECS)
            ctx = svc.context_for(SPECS, cost_model=model)
            with pytest.raises(ValueError, match="cost model"):
                orchestrator.compile(
                    SPECS, MinEnergy(deadline_s=DEADLINE), acc=ACC,
                    ctx=ctx, cost_model=sparsity_cost_model(0.7, SPECS))
            # None inherits the context's model
            sched = orchestrator.compile(
                SPECS, MinEnergy(deadline_s=DEADLINE), acc=ACC, ctx=ctx)
        assert sched.cost_model == model.digest

    def test_harness_parity_model_compiles_identical(self):
        """A calibration measured from the analytic model itself (all
        ratios 1.0) must compile bit-identical schedules to static."""
        table = run_harness(ACC, HarnessConfig(repeats=1))
        model = table.cost_model(SPECS)
        with CompileService(ACC) as svc:
            static = svc.compile(SPECS, goal=MinEnergy(
                deadline_s=DEADLINE))
            cal = svc.compile(SPECS, goal=MinEnergy(
                deadline_s=DEADLINE), cost_model=model)
        assert _same_schedule(static, cal)


# ------------------------------------------------------- policy table

class TestPolicyTable:
    def test_sparsity_model(self):
        m = sparsity_cost_model(0.4, SPECS)
        # conv and fc scale; pool holds
        assert m.scale == (0.4, 1.0, 0.4)
        assert sparsity_cost_model(0.01, SPECS).scale[0] == 0.05  # floor
        with pytest.raises(ValueError, match="density"):
            sparsity_cost_model(0.0, SPECS)
        with pytest.raises(ValueError, match="floor"):
            sparsity_cost_model(0.5, SPECS, floor=0.0)

    def test_table_validation(self):
        m = identity_model(3)
        with pytest.raises(ValueError, match="band"):
            SchedulePolicyTable("density", [])
        overlapping = [
            PolicyBand(0.0, 0.6, m, {}),
            PolicyBand(0.5, 1.0, m, {}),
        ]
        with pytest.raises(ValueError, match="overlap"):
            SchedulePolicyTable("density", overlapping)

    def test_band_and_deadline_snapping(self):
        m = identity_model(3)
        s_lo, s_hi = object(), object()
        bands = [PolicyBand(0.0, 0.5, m, {0.01: s_lo, 0.02: s_hi})]
        table = SchedulePolicyTable("density", bands)
        assert table.band_for(-1.0) is bands[0]   # clamps below
        assert table.band_for(2.0) is bands[0]    # clamps above
        assert table.lookup(0.2, 0.015) is s_lo   # largest <= request
        assert table.lookup(0.2, 0.005) is s_lo   # tighter than grid ->
        assert table.lookup(0.2, 0.5) is s_hi     # fastest available
        assert table.deadlines() == [0.01, 0.02]

    def test_compile_validation(self):
        with CompileService(ACC) as svc:
            with pytest.raises(ValueError, match="band_edges"):
                compile_policy_table(svc, SPECS, band_edges=[0.5],
                                     deadlines=[DEADLINE])
            with pytest.raises(ValueError, match="deadline"):
                compile_policy_table(svc, SPECS,
                                     band_edges=[0.0, 1.0], deadlines=[])

    def test_family_identical_to_solo_compiles(self):
        """The acceptance pin: every (band, deadline) entry of the
        fleet-compiled family is bit-identical to a solo compile under
        the same cost model on a fresh service."""
        deadlines = [DEADLINE, 2 * DEADLINE]
        with CompileService(ACC) as svc:
            table = compile_policy_table(
                svc, SPECS, band_edges=[0.0, 0.5, 1.0],
                deadlines=deadlines)
        assert len(table.bands) == 2
        for band in table.bands:
            assert sorted(band.schedules) == sorted(deadlines)
            assert not band.infeasible
            for d, sched in band.schedules.items():
                with CompileService(ACC) as solo_svc:
                    solo = solo_svc.compile(
                        SPECS, goal=MinEnergy(deadline_s=d),
                        cost_model=band.cost_model)
                assert _same_schedule(sched, solo)
                assert sched.cost_model == band.cost_model.digest

    def test_denser_band_costs_more_energy(self):
        with CompileService(ACC) as svc:
            table = compile_policy_table(
                svc, SPECS, band_edges=[0.0, 0.4, 1.0],
                deadlines=[DEADLINE])
        sparse = table.lookup(0.2, DEADLINE)
        dense = table.lookup(0.8, DEADLINE)
        assert sparse.e_total < dense.e_total


# ------------------------------------------- adaptive learning plane

def _bundle_and_runtime(svc, rate):
    costs = characterize_network(SPECS, ACC)
    plan = plan_banks(costs, ACC)
    bundle = svc.compile_contingencies(SPECS, rate, network="net")
    return bundle, costs, plan


class TestAdaptivePlaneCalibration:
    def test_config_validation(self):
        with pytest.raises(ValueError, match="calib_threshold"):
            AdaptiveConfig(calib_threshold=0.0)
        with pytest.raises(ValueError, match="calib_min_samples"):
            AdaptiveConfig(calib_window=4, calib_min_samples=8)
        with pytest.raises(ValueError, match="calib_cooldown"):
            AdaptiveConfig(calib_cooldown=-1)

    def test_blocking_recalibration_recenters(self):
        rate = 60.0
        n = 120
        times = np.arange(n + 1) / rate
        with CompileService(ACC) as svc:
            bundle, costs, plan = _bundle_and_runtime(svc, rate)
            acfg = AdaptiveConfig(
                calib_enabled=True, calib_blocking=True,
                calib_window=12, calib_min_samples=6,
                calib_cooldown=12)
            plane = AdaptiveScheduler(
                bundle, costs, plan, ACC, service=svc, specs=SPECS,
                acfg=acfg)
            inj = FaultInjector(
                FaultConfig(seed=5, op_sigma=0.01), len(SPECS),
                op_bias=linear_drift(0.25 / (n // 2), peak=n // 2))
            report = serve_trace(times, plane, injector=inj)
        assert report.served == n
        starts = plane.events.of("calibrate_start")
        dones = plane.events.of("calibrate_done")
        assert starts and len(dones) == len(starts)
        assert all(e.detail["blocking"] for e in starts)
        # the re-solve replaced live snap points (the base deadline is
        # always on the regenerated grid)
        assert any(e.detail["replaced_points"] > 0 for e in dones)
        # the plane's applied correction moved off identity
        assert not np.allclose(plane._applied_scale, 1.0)

    def test_calibration_disabled_never_estimates(self):
        rate = 60.0
        times = np.arange(41) / rate
        with CompileService(ACC) as svc:
            bundle, costs, plan = _bundle_and_runtime(svc, rate)
            plane = AdaptiveScheduler(bundle, costs, plan, ACC,
                                      service=svc, specs=SPECS)
            inj = FaultInjector(
                FaultConfig(seed=5, op_sigma=0.01), len(SPECS),
                op_bias=linear_drift(0.01))
            serve_trace(times, plane, injector=inj)
        assert plane._estimator is None
        assert not plane.events.of("calibrate_start")

    def test_policy_table_axis(self):
        rate = 60.0
        n = 40
        times = np.arange(n + 1) / rate
        with CompileService(ACC) as svc:
            bundle, costs, plan = _bundle_and_runtime(svc, rate)
            table = compile_policy_table(
                svc, SPECS, band_edges=[0.0, 0.5, 1.0],
                deadlines=[1.0 / rate * 0.85])
            plane = AdaptiveScheduler(bundle, costs, plan, ACC,
                                      policy_table=table)
            obs = np.where(np.arange(n) < n // 2, 0.2, 0.8)
            report = serve_trace(times, plane, observables=obs)
        snaps = plane.events.of("snap")
        table_snaps = [e for e in snaps
                       if e.detail.get("variant") == "policy_table"]
        # one snap per band regime
        assert len(table_snaps) == 2
        bands = [tuple(e.detail["band"]) for e in table_snaps]
        assert bands == [(0.0, 0.5), (0.5, 1.0)]
        assert report.served == n

    def test_observables_shape_validated(self):
        rate = 60.0
        times = np.arange(5) / rate
        with CompileService(ACC) as svc:
            bundle, costs, plan = _bundle_and_runtime(svc, rate)
            plane = AdaptiveScheduler(bundle, costs, plan, ACC)
            with pytest.raises(ValueError, match="observables"):
                serve_trace(times, plane,
                            observables=np.zeros(3))


# ----------------------------------------------- FaultConfig validation

class TestFaultConfigValidation:
    def test_defaults_valid(self):
        FaultConfig()

    @pytest.mark.parametrize("field", ["op_sigma", "trans_sigma",
                                       "late_max_s"])
    def test_negative_magnitudes_rejected(self, field):
        with pytest.raises(ValueError, match=field):
            FaultConfig(**{field: -0.1})

    @pytest.mark.parametrize("field", ["p_trans_spike", "p_drop",
                                       "p_late"])
    def test_probabilities_bounded(self, field):
        with pytest.raises(ValueError, match=field):
            FaultConfig(**{field: -0.01})
        with pytest.raises(ValueError, match=field):
            FaultConfig(**{field: 1.5})
        FaultConfig(**{field: 1.0})     # boundary is legal

    def test_nan_rejected(self):
        with pytest.raises(ValueError, match="op_sigma"):
            FaultConfig(op_sigma=float("nan"))

    def test_spike_mult_positive(self):
        with pytest.raises(ValueError, match="trans_spike_mult"):
            FaultConfig(trans_spike_mult=0.0)
