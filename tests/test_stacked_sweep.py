"""Subset-stacked sweep engine + backend k-best frontier: equivalence
with the scalar / sequential implementations.

The contracts under test (see ISSUE 3 / ROADMAP):
  - the backend ``kbest_multi`` frontier (numpy + jitted jax) matches
    the scalar pure-numpy ``kbest_paths`` kernel per μ, exactly;
  - every stacked kernel is per-lane bit-identical to the non-stacked
    kernel on that lane's own (re-padded) tensors;
  - ``select_rails_stacked`` selects the identical
    ``(best_subset, e_total, path)`` as the sequential ``select_rails``
    across random level sets, deadlines, bucket mixes, live caps, and
    worker counts — ties and infeasible subsets included;
  - the golden pipeline passes under ``stack_subsets=True`` on both
    backends, and the legacy per-subset path stays intact behind
    ``stack_subsets=False``.
"""

import json
import pathlib

import numpy as np
import pytest

from conftest import max_rate, random_problem
from repro.core import (
    OrchestratorConfig,
    StackedLambdaTask,
    available_backends,
    compile_power_schedule,
    kbest_paths,
    kbest_paths_multi,
    get_backend,
    select_rails,
    select_rails_stacked,
    solve_lambda_dp,
)
from repro.core.lambda_dp import kbest_rows_to_lists
from repro.core.backend import build_padded, repad, stack_padded
from repro.core.problem import IdleModel, ScheduleProblem, StateCost
from repro.core.rails import all_rail_subsets
from repro.hw.dvfs import TransitionModel
from repro.models.edge_cnn import edge_network

GOLDEN = json.loads(
    (pathlib.Path(__file__).parent / "golden" / "pipeline.json")
    .read_text())

BACKENDS = list(available_backends())


# --------------------------------------- backend k-best frontier parity

@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("seed", range(4))
def test_kbest_multi_matches_scalar_kernel(backend, seed):
    """The pluggable-backend fused multi-μ frontier returns exactly the
    scalar pure-numpy ``kbest_paths`` per μ — non-stacked path."""
    rng = np.random.default_rng(seed)
    prob = random_problem(rng, n_layers=5, n_states=4)
    mus = [0.0, -prob.idle.p_sleep, 1e-3, 0.7, 50.0]
    k = 6
    multi = kbest_paths_multi(prob, mus, k, backend=backend)
    for q, mu in enumerate(mus):
        assert multi[q] == kbest_paths(prob, mu, k), (backend, mu)


@pytest.mark.parametrize("backend", BACKENDS)
def test_kbest_stacked_matches_per_lane(backend):
    """Stacked frontier lanes are bit-identical to the non-stacked
    kernel on each lane's own re-padded tensors (mixed buckets)."""
    bk = get_backend(backend)
    rng = np.random.default_rng(7)
    problems = [random_problem(rng, n_layers=5, n_states=n)
                for n in (3, 5, 4, 7)]         # buckets 4 and 8 mixed
    padded = [build_padded(p) for p in problems]
    sp = max(p.s_pad for p in padded)
    stack = stack_padded([repad(p, sp) for p in padded])
    mus = np.array([[0.0, 3.5], [1e-3, 50.0], [0.7, 0.7], [-1e-5, 2.0]])
    k = 5
    paths, counts = bk.kbest_multi_stacked(stack, mus, k)
    for b, p in enumerate(padded):
        ref_p, ref_c = bk.kbest_multi(repad(p, sp), mus[b], k)
        np.testing.assert_array_equal(counts[b], ref_c)
        assert kbest_rows_to_lists(paths[b], counts[b]) == \
            kbest_rows_to_lists(ref_p, ref_c)


@pytest.mark.parametrize("backend", BACKENDS)
def test_dp_stacked_matches_per_lane(backend):
    bk = get_backend(backend)
    rng = np.random.default_rng(11)
    problems = [random_problem(rng, n_layers=6, n_states=n)
                for n in (4, 6, 3)]
    padded = [build_padded(p) for p in problems]
    sp = max(p.s_pad for p in padded)
    stack = stack_padded([repad(p, sp) for p in padded])
    w_t = np.array([[0.0, 1e-3, 4.0], [1.0, 0.5, 60.0], [0.0, 0.0, 9.0]])
    w_e = np.ones_like(w_t)
    w_e[0, 0] = 0.0                            # a min-time row
    paths = bk.dp_multi_stacked(stack, w_e, w_t)
    for b, p in enumerate(padded):
        ref = bk.dp_multi(repad(p, sp), w_e[b], w_t[b])
        np.testing.assert_array_equal(paths[b], ref)


@pytest.mark.parametrize("backend", BACKENDS)
def test_path_costs_stacked_matches_per_lane(backend):
    bk = get_backend(backend)
    rng = np.random.default_rng(13)
    problems = [random_problem(rng, n_layers=5, n_states=4)
                for _ in range(3)]
    padded = [build_padded(p) for p in problems]
    stack = stack_padded(padded)
    paths = np.array([[int(rng.integers(4)) for _ in range(5)]
                      for _ in range(9)])
    lanes = np.array([0, 1, 2, 2, 1, 0, 1, 0, 2])
    got = bk.path_costs_stacked(stack, lanes, paths)
    for r in range(len(paths)):
        ref = bk.path_costs(problems[lanes[r]], paths[r:r + 1])
        for key in ("t_op", "e_op", "t_trans", "e_trans", "n_switch"):
            assert got[key][r] == ref[key][0], (backend, key, r)


@pytest.mark.skipif("jax" not in BACKENDS, reason="jax not installed")
def test_jax_jitted_kernels_match_numpy(monkeypatch):
    """Force the jitted scan kernels (the CPU heuristics would route
    these small slabs to the host numpy kernels) and pin exact parity
    for the DP and the k-best frontier, stacked and non-stacked."""
    bk = get_backend("jax")
    monkeypatch.setattr(type(bk), "_JIT_MIN_WORK", 0)
    monkeypatch.setattr(type(bk), "_KBEST_JIT_MIN_WORK", 0)
    ref = get_backend("numpy")
    rng = np.random.default_rng(3)
    problems = [random_problem(rng, n_layers=5, n_states=n)
                for n in (4, 6)]
    padded = [build_padded(p) for p in problems]
    sp = max(p.s_pad for p in padded)
    stack = stack_padded([repad(p, sp) for p in padded])
    mus = np.array([[0.0, 4.0], [1e-3, 30.0]])
    for b, p in enumerate(padded):
        np.testing.assert_array_equal(
            bk.dp_multi(p, np.ones(2), mus[b]),
            ref.dp_multi(p, np.ones(2), mus[b]))
        jp, jc = bk.kbest_multi(p, mus[b], 4)
        rp, rc = ref.kbest_multi(p, mus[b], 4)
        np.testing.assert_array_equal(jc, rc)
        assert kbest_rows_to_lists(jp, jc) == kbest_rows_to_lists(rp, rc)
    np.testing.assert_array_equal(
        bk.dp_multi_stacked(stack, np.ones((2, 2)), mus),
        ref.dp_multi_stacked(stack, np.ones((2, 2)), mus))
    jp, jc = bk.kbest_multi_stacked(stack, mus, 4)
    rp, rc = ref.kbest_multi_stacked(stack, mus, 4)
    np.testing.assert_array_equal(jc, rc)
    for b in range(2):
        assert kbest_rows_to_lists(jp[b], jc[b]) == \
            kbest_rows_to_lists(rp[b], rc[b])


# ------------------------------- stacked sweep vs sequential selection

class _MasterInstance:
    """A random sweep instance with sound cuts: per-layer latency is
    monotone non-increasing in voltage (so the infeasibility ceiling is
    exact, as on the real accelerator) and Σ min E_op is a true lower
    bound (so the incumbent cut is sound)."""

    def __init__(self, seed: int, n_layers: int, n_levels: int,
                 thresh_frac: float, tie_energies: bool):
        rng = np.random.default_rng(seed)
        self.levels = tuple(sorted(
            round(float(v), 3)
            for v in rng.uniform(0.7, 1.3, size=n_levels)))
        self.base_t = rng.uniform(1e-4, 1e-3, size=n_layers)
        if tie_energies:
            # energy independent of voltage → whole size classes of
            # subsets tie on e_total; enumeration order must break them
            self.base_e = np.repeat(
                rng.uniform(1e-6, 1e-4, size=(n_layers, 1)),
                n_levels, axis=1)
        else:
            self.base_e = rng.uniform(1e-6, 1e-4,
                                      size=(n_layers, n_levels))
        # deadline set so subsets whose max rail is below a threshold
        # level are provably infeasible (exercises the vmax ceiling)
        lo, hi = min(self.levels), max(self.levels)
        v_thresh = lo + thresh_frac * (hi - lo)
        self.t_max = float(self.base_t.sum() / v_thresh)
        self.idle = IdleModel(p_idle=1e-3, p_sleep=1e-5,
                              e_sleep_wake=1e-8, t_sleep_wake=1e-6)
        self.tm = TransitionModel(v_min=lo, v_max=hi)

    def problem(self, rails: tuple[float, ...]) -> ScheduleProblem:
        cols = [self.levels.index(v) for v in sorted(rails)]
        layers = [[StateCost(voltages=(self.levels[c],),
                             t_op=float(self.base_t[i] / self.levels[c]),
                             e_op=float(self.base_e[i][c]))
                   for c in cols]
                  for i in range(len(self.base_t))]
        return ScheduleProblem(layer_states=layers, t_max=self.t_max,
                               idle=self.idle, transition_model=self.tm,
                               rails=tuple(sorted(rails)))

    def bound(self, rails: tuple[float, ...]) -> float:
        cols = [self.levels.index(v) for v in sorted(rails)]
        return float(self.base_e[:, cols].min(axis=1).sum())


def _sweep_both_ways(inst: _MasterInstance, n_max: int, *,
                     max_live: int, workers: int | None = None):
    def solve_fn(subset):
        best, _, stats = solve_lambda_dp(inst.problem(subset))
        if best is None:
            return None
        best = dict(best)
        best["rails"] = subset
        best["lambda_star"] = stats.lambda_star
        return best

    def make_task(idx, subset, hint=None):
        # hint deliberately ignored: identical probe sequences are what
        # make the stacked-vs-sequential comparison exact
        return StackedLambdaTask(idx, subset, inst.problem(subset))

    seq = select_rails(inst.levels, n_max, solve_fn,
                       bound_fn=inst.bound, workers=workers)
    stk = select_rails_stacked(
        all_rail_subsets(inst.levels, n_max), make_task,
        bound_fn=inst.bound, max_live=max_live)
    return seq, stk


@pytest.mark.parametrize("seed,max_live", [(0, 1), (1, 3), (2, 16),
                                           (3, 5), (4, 16)])
def test_stacked_sweep_matches_sequential(seed, max_live):
    inst = _MasterInstance(seed, n_layers=4, n_levels=4,
                           thresh_frac=0.5, tie_energies=False)
    (b_seq, s_seq, st_seq), (b_stk, s_stk, st_stk) = _sweep_both_ways(
        inst, 3, max_live=max_live)
    assert (b_seq is None) == (b_stk is None)
    assert s_stk == s_seq
    if b_seq is not None:
        assert b_stk["e_total"] == b_seq["e_total"]      # bit-identical
        assert b_stk["path"] == b_seq["path"]
    assert st_stk["subsets_total"] == st_seq["subsets_total"]
    assert (st_stk["subsets_solved"] + st_stk["subsets_skipped"]
            + st_stk["subsets_cut"]) == st_stk["subsets_total"]


def test_stacked_sweep_ties_and_infeasible_band():
    """Size-class e_total ties + an infeasible low-voltage band: the
    stacked scheduler must keep the sequential tie winner (earliest in
    enumeration order) no matter how rounds interleave."""
    for seed in range(3):
        inst = _MasterInstance(seed, n_layers=3, n_levels=5,
                               thresh_frac=0.6, tie_energies=True)
        for max_live in (1, 4, 16):
            (b_seq, s_seq, _), (b_stk, s_stk, _) = _sweep_both_ways(
                inst, 2, max_live=max_live)
            assert s_stk == s_seq, (seed, max_live)
            if b_seq is not None:
                assert b_stk["e_total"] == b_seq["e_total"]


def test_stacked_sweep_all_infeasible():
    inst = _MasterInstance(5, n_layers=3, n_levels=3,
                           thresh_frac=0.5, tie_energies=False)
    inst.t_max = 1e-9                     # nothing can meet the deadline
    (b_seq, s_seq, _), (b_stk, s_stk, st) = _sweep_both_ways(
        inst, 2, max_live=4)
    assert b_seq is None and b_stk is None
    assert s_seq is None and s_stk is None
    assert st["subsets_solved"] + st["subsets_skipped"] \
        + st["subsets_cut"] == st["subsets_total"]


try:
    from hypothesis import given, settings, strategies as hst

    @settings(max_examples=12, deadline=None)
    @given(seed=hst.integers(0, 10_000),
           n_layers=hst.integers(2, 5),
           n_levels=hst.integers(3, 5),
           thresh_frac=hst.floats(0.0, 1.2),
           tie=hst.booleans(),
           max_live=hst.sampled_from([1, 2, 4, 16]),
           workers=hst.sampled_from([None, 3]))
    def test_property_stacked_equals_sequential(seed, n_layers, n_levels,
                                                thresh_frac, tie,
                                                max_live, workers):
        """Random level sets, deadlines, bucket mixes, live caps, and
        worker counts: identical (best_subset, e_total, rails)."""
        inst = _MasterInstance(seed, n_layers, n_levels, thresh_frac, tie)
        (b_seq, s_seq, _), (b_stk, s_stk, _) = _sweep_both_ways(
            inst, 2, max_live=max_live, workers=workers)
        assert s_stk == s_seq
        assert (b_seq is None) == (b_stk is None)
        if b_seq is not None:
            assert b_stk["e_total"] == b_seq["e_total"]
            assert b_stk["rails"] == b_seq["rails"]
except ImportError:                                  # pragma: no cover
    pass


def test_aborted_run_evicts_member_stacks():
    """A fleet that dies mid-round (backend error, interrupt) must not
    strand its uid-keyed member stacks in a possibly store-owned
    StackCaches — no later run can ever hit those keys."""
    from repro.core.backend import NumpyBackend, StackCaches
    from repro.core.rails import StackedSweep, run_stacked_sweeps

    class Boom(Exception):
        pass

    class FailingBackend(NumpyBackend):
        def __init__(self):
            self.calls = 0

        def dp_multi_stacked(self, *args, **kwargs):
            self.calls += 1
            if self.calls >= 2:
                raise Boom()
            return super().dp_multi_stacked(*args, **kwargs)

    inst = _MasterInstance(0, n_layers=4, n_levels=4,
                           thresh_frac=0.5, tie_energies=False)
    caches = StackCaches()
    sweep = StackedSweep(
        all_rail_subsets(inst.levels, 3),
        lambda idx, s, hint=None: StackedLambdaTask(
            idx, s, inst.problem(s)))
    with pytest.raises(Boom):
        run_stacked_sweeps([sweep], backend=FailingBackend(),
                           caches=caches)
    assert caches.member_stacks == {}


# ------------------------------------------ end-to-end + golden pins

def _compile(network, frac, n_rails, policy, **cfg_kwargs):
    return compile_power_schedule(
        edge_network(network), max_rate(network) * frac,
        cfg=OrchestratorConfig(policy=policy, n_max_rails=n_rails,
                               **cfg_kwargs),
        network=network)


def test_batch_lambda_off_routes_to_legacy_sweep():
    """batch_lambda=False means the legacy scalar bisection — the
    stacked engine (which is the batched machine by construction) must
    step aside even when stack_subsets is left at its default."""
    s = _compile("squeezenet1.1", 0.9, 2, "pfdnn", batch_lambda=False)
    assert "stacked_rounds" not in s.solver_stats
    ref = _compile("squeezenet1.1", 0.9, 2, "pfdnn")
    assert s.rails == ref.rails
    assert s.e_total == pytest.approx(ref.e_total, rel=1e-9)


def test_stacked_compile_matches_legacy_sweep():
    stacked = _compile("squeezenet1.1", 0.9, 2, "pfdnn",
                       stack_subsets=True)
    legacy = _compile("squeezenet1.1", 0.9, 2, "pfdnn",
                      stack_subsets=False)
    assert stacked.rails == legacy.rails
    assert stacked.layer_voltages == legacy.layer_voltages
    assert stacked.e_total == pytest.approx(legacy.e_total, rel=1e-9)
    assert "stacked_rounds" in stacked.solver_stats
    assert "stacked_rounds" not in legacy.solver_stats


@pytest.mark.parametrize("backend", BACKENDS)
def test_golden_pipeline_under_stacked_sweep(backend):
    key = "squeezenet1.1|0.9|2|pfdnn"
    golden = GOLDEN[key]
    network, frac, n_rails, policy = key.split("|")
    s = _compile(network, float(frac), int(n_rails), policy,
                 backend=backend, stack_subsets=True)
    assert s.e_total == pytest.approx(golden["e_total"], rel=1e-9)
    assert list(s.rails) == golden["rails"]
    assert [list(v) for v in s.layer_voltages] == golden["layer_voltages"]


def test_golden_pipeline_under_legacy_sweep():
    key = "squeezenet1.1|0.9|2|pfdnn"
    golden = GOLDEN[key]
    network, frac, n_rails, policy = key.split("|")
    s = _compile(network, float(frac), int(n_rails), policy,
                 stack_subsets=False)
    assert s.e_total == pytest.approx(golden["e_total"], rel=1e-9)
    assert list(s.rails) == golden["rails"]
    assert [list(v) for v in s.layer_voltages] == golden["layer_voltages"]
