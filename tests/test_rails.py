"""Rail-subset machinery: the select_rails dominance shortcut, the
warm-start hint protocol, the incumbent bound cut, and
evenly_spaced_rails edge cases (paper §3.3, §6.3)."""

import numpy as np
import pytest

from repro.core.rails import (
    all_rail_subsets,
    evenly_spaced_rails,
    select_rails,
)

LEVELS = tuple(round(0.9 + 0.05 * i, 4) for i in range(9))


def _synthetic_solver(v_crit: float, rng: np.random.Generator):
    """Feasible iff max(subset) >= v_crit — matching the monotone
    assumption the dominance shortcut relies on (per-layer latency is
    non-increasing in voltage)."""
    energies: dict[tuple, float] = {}

    def solve(subset):
        if max(subset) < v_crit:
            return None
        if subset not in energies:
            energies[subset] = float(rng.uniform(1.0, 2.0))
        return {"e_total": energies[subset], "path": []}

    return solve, energies


@pytest.mark.parametrize("seed", range(5))
def test_dominance_shortcut_never_skips_a_feasible_subset(seed):
    rng = np.random.default_rng(seed)
    v_crit = float(rng.choice(LEVELS[2:]))
    solve, energies = _synthetic_solver(v_crit, rng)
    best, best_subset, stats = select_rails(LEVELS, 3, solve)
    # brute force over every subset, no shortcut
    exhaustive = {s: solve(s) for s in all_rail_subsets(LEVELS, 3)}
    feasible = {s: r for s, r in exhaustive.items() if r is not None}
    assert best is not None
    assert best["e_total"] == min(r["e_total"] for r in feasible.values())
    assert best_subset in feasible
    # the shortcut only ever skipped infeasible subsets
    assert stats["subsets_skipped"] > 0
    assert stats["subsets_solved"] + stats["subsets_skipped"] \
        == stats["subsets_total"]
    n_infeasible = sum(r is None for r in exhaustive.values())
    assert stats["subsets_skipped"] <= n_infeasible


def test_all_infeasible_returns_none_and_skips_dominated():
    solve = lambda subset: None
    best, best_subset, stats = select_rails(LEVELS, 2, solve)
    assert best is None and best_subset is None
    assert stats["subsets_solved"] >= 1
    # once (1.3,)-headed subsets fail, every lower-max subset is skipped
    assert stats["subsets_skipped"] > 0


def test_hint_protocol_passes_lambda_star():
    seen_hints = []

    def solve(subset, hint):
        seen_hints.append(dict(hint))
        return {"e_total": 2.0 - max(subset),
                "lambda_star": max(subset) * 10.0}

    best, best_subset, _ = select_rails(LEVELS, 1, solve)
    assert best_subset == (1.3,)           # highest max rail wins here
    # first call: no hint yet
    assert seen_hints[0] == {"lam_hint": None}
    # later calls carry the previous subset's λ*
    assert seen_hints[1]["lam_hint"] == pytest.approx(13.0)
    for h in seen_hints[2:]:
        assert h["lam_hint"] is not None


def test_hint_never_passed_to_unrelated_second_parameter():
    """A solver without a declared ``hint`` parameter must be called
    with the subset only — even if it has other optional parameters."""
    calls = []

    def solve(subset, retries=3):
        calls.append(retries)
        return {"e_total": 1.0}

    select_rails(LEVELS, 1, solve)
    assert all(r == 3 for r in calls)      # default untouched, no dict


def test_incumbent_bound_cut_is_sound():
    """Cutting on a true lower bound never changes the selected subset."""
    rng = np.random.default_rng(7)
    energies = {s: float(rng.uniform(1.0, 2.0))
                for s in all_rail_subsets(LEVELS, 2)}

    def solve(subset):
        return {"e_total": energies[subset]}

    def bound(subset):
        return energies[subset] * 0.9      # sound: below the true value

    plain = select_rails(LEVELS, 2, solve)
    cut = select_rails(LEVELS, 2, solve, bound_fn=bound)
    assert cut[1] == plain[1]
    assert cut[0]["e_total"] == plain[0]["e_total"]
    assert cut[2]["subsets_cut"] > 0
    assert cut[2]["subsets_solved"] < plain[2]["subsets_solved"]


# ------------------------------------------------- evenly_spaced_rails

def test_evenly_spaced_k1_is_vmax():
    assert evenly_spaced_rails(LEVELS, 1) == (LEVELS[-1],)


def test_evenly_spaced_k_equals_len_levels_is_identity():
    assert evenly_spaced_rails(LEVELS, len(LEVELS)) == tuple(LEVELS)


def test_evenly_spaced_k_beyond_levels_raises():
    # k beyond |distinct V| cannot invent levels: configuration error
    with pytest.raises(ValueError, match="distinct"):
        evenly_spaced_rails(LEVELS, len(LEVELS) + 3)
    with pytest.raises(ValueError, match="at least one"):
        evenly_spaced_rails(LEVELS, 0)


@pytest.mark.parametrize("k", range(1, 10))
def test_evenly_spaced_invariants(k):
    rails = evenly_spaced_rails(LEVELS, k)
    assert LEVELS[-1] in rails             # V_max always reachable
    assert list(rails) == sorted(rails)    # sorted ...
    assert len(set(rails)) == len(rails)   # ... and duplicate-free
    assert set(rails) <= set(LEVELS)
    assert len(rails) == k                 # exactly k, never fewer


def test_evenly_spaced_unsorted_input():
    shuffled = tuple(reversed(LEVELS))
    assert evenly_spaced_rails(shuffled, 3) == \
        evenly_spaced_rails(LEVELS, 3)


def test_evenly_spaced_backfills_collapsed_picks():
    """Duplicate levels used to collapse the linspace picks and return
    fewer than k rails; the picks are now backfilled with the nearest
    unused levels so exactly k distinct rails come back."""
    levels = (1.0, 1.0, 1.0, 1.1, 1.3)     # 3 distinct
    rails = evenly_spaced_rails(levels, 3)
    assert rails == (1.0, 1.1, 1.3)
    with pytest.raises(ValueError, match="distinct"):
        evenly_spaced_rails(levels, 4)


@pytest.mark.parametrize("n_levels,k", [(4, 3), (5, 4), (7, 6), (9, 5)])
def test_evenly_spaced_always_exactly_k(n_levels, k):
    levels = tuple(round(0.9 + 0.05 * i, 4) for i in range(n_levels))
    rails = evenly_spaced_rails(levels, k)
    assert len(rails) == k
    assert set(rails) <= set(levels)
    assert levels[-1] in rails
